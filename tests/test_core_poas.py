"""Unit + property tests for the POAS core (predict/optimize/adapt/schedule)."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # collection must never hard-error (see requirements-dev)
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed "
            "(pip install -r requirements-dev.txt)")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # placeholder strategies; only consumed by decorator args
        floats = integers = lists = staticmethod(lambda *a, **k: None)

from repro.core import (CopyModel, DeviceProfile, GemmWorkload, HGemms,
                        LinearTimeModel, NO_COPY, DynamicScheduler,
                        StaticScheduler, decompose_square, fit_linear,
                        ops_to_mnk, paper_mach1, paper_mach2, priority_order,
                        relative_error, rmse, simulate_timeline, squareness,
                        solve_analytic, solve_bisection, solve_local_search,
                        Profiler, simulated_runner, save_profiles,
                        load_profiles)


def _mk(name, tflops, bw=None, align=1, b=1e-4):
    ops_per_s = tflops * 1e12 / 2
    copy = NO_COPY if bw is None else CopyModel(bw, dtype_size=4)
    return DeviceProfile(name, "gpu" if bw else "cpu",
                         LinearTimeModel(a=1 / ops_per_s, b=b), copy,
                         align_m=align)


# ---------------------------------------------------------------- predict --

def test_fit_linear_recovers_model():
    truth = LinearTimeModel(a=2.5e-12, b=3e-3)
    xs = np.linspace(1e9, 64e9, 20)
    ys = [truth(x) for x in xs]
    fit = fit_linear(xs, ys)
    assert fit.a == pytest.approx(truth.a, rel=1e-9)
    assert fit.b == pytest.approx(truth.b, rel=1e-6)


def test_fit_linear_noise_robust():
    rng = np.random.default_rng(0)
    truth = LinearTimeModel(a=1e-12, b=1e-3)
    xs = np.linspace(1e9, 27e9, 30)
    ys = [truth(x) * (1 + 0.02 * rng.standard_normal()) for x in xs]
    fit = fit_linear(xs, ys)
    assert fit.a == pytest.approx(truth.a, rel=0.05)


def test_profiler_simulated_roundtrip():
    dev = _mk("sim", 10.0)
    prof = Profiler(simulated_runner(dev, noise=0.01), repeats=5)
    prof.run(range(1000, 2001, 100))
    fit = prof.fit()
    assert fit.a == pytest.approx(dev.compute.a, rel=0.1)


def test_relative_error_and_rmse():
    assert relative_error(95.0, 100.0) == pytest.approx(5.0)
    assert rmse([3.0, 4.0]) == pytest.approx(math.sqrt(12.5))


def test_profile_persistence(tmp_path):
    devs = paper_mach1()
    path = tmp_path / "profiles.json"
    save_profiles(str(path), devs)
    loaded = load_profiles(str(path))
    assert [d.name for d in loaded] == [d.name for d in devs]
    assert loaded[1].compute.a == pytest.approx(devs[1].compute.a)
    assert loaded[1].copy.bandwidth_bytes_per_s == pytest.approx(
        devs[1].copy.bandwidth_bytes_per_s)


# --------------------------------------------------------------- optimize --

def test_bisection_matches_analytic_linear():
    devs = [_mk("cpu", 1.0), _mk("gpu", 10.0, bw=16e9), _mk("xpu", 40.0, bw=16e9)]
    N, n, k = 8e12, 20000, 20000
    b = solve_bisection(devs, N, n=n, k=k, bus="independent")
    a = solve_analytic(devs, N, n=n, k=k)
    assert b.makespan == pytest.approx(a.makespan, rel=1e-6)
    for x, y in zip(b.ops, a.ops):
        assert x == pytest.approx(y, rel=1e-4)


def test_bisection_matches_local_search():
    devs = paper_mach2()
    N, n, k = 27e12, 30000, 30000
    b = solve_bisection(devs, N, n=n, k=k, bus="serialized")
    ls = solve_local_search(devs, N, n=n, k=k, bus="serialized")
    # local search is approximate; bisection must be at least as good
    assert b.makespan <= ls.makespan * 1.001
    assert b.makespan == pytest.approx(ls.makespan, rel=0.02)


def test_ops_conservation():
    devs = paper_mach1()
    N = 42e12
    r = solve_bisection(devs, N, n=20000, k=35000, bus="serialized")
    assert sum(r.ops) == pytest.approx(N, rel=1e-9)
    assert all(c >= 0 for c in r.ops)


def test_single_device_degenerates():
    devs = [_mk("only", 5.0)]
    r = solve_bisection(devs, 1e12, n=1000, k=1000)
    assert r.ops[0] == pytest.approx(1e12)
    assert r.makespan == pytest.approx(devs[0].compute(1e12), rel=1e-6)


def test_faster_device_gets_more_work():
    devs = [_mk("slow", 1.0), _mk("fast", 10.0)]
    r = solve_bisection(devs, 1e13, n=10000, k=10000)
    assert r.ops[1] > 5 * r.ops[0]


@settings(max_examples=30, deadline=None)
@given(tf1=st.floats(0.5, 50), tf2=st.floats(0.5, 50),
       npow=st.integers(10, 14))
def test_bisection_optimality_property(tf1, tf2, npow):
    """Property: no rebalancing of the bisection split improves the makespan
    (checked against a dense sweep of alternative splits)."""
    devs = [_mk("a", tf1), _mk("b", tf2, bw=16e9)]
    N = float(2 ** npow) * 1e9
    n = k = 4000
    r = solve_bisection(devs, N, n=n, k=k, bus="independent")
    best = min(max(devs[0].total_time(f * N, n, k),
                   devs[1].total_time((1 - f) * N, n, k))
               for f in np.linspace(0, 1, 2001))
    assert r.makespan <= best * 1.001


# ------------------------------------------------------------------ adapt --

def test_ops_to_mnk_rows_conserved():
    devs = paper_mach1()
    m, n, k = 30000, 30000, 30000
    r = solve_bisection(devs, float(m) * n * k, n=n, k=k, bus="serialized")
    plan = ops_to_mnk(devs, r.ops, m, n, k)
    assert plan.total_rows() == m
    offs = 0
    for a in plan.assignments:
        assert a.row0 == offs
        offs += a.m


def test_ops_to_mnk_alignment():
    devs = paper_mach1()  # xpu has align_m=8
    m, n, k = 30001, 4096, 4096
    r = solve_bisection(devs, float(m) * n * k, n=n, k=k)
    plan = ops_to_mnk(devs, r.ops, m, n, k)
    xpu = plan.assignments[2]
    # alignment is best-effort: xpu rows must be a multiple of 8 unless the
    # leftover forced a remainder packet
    assert plan.total_rows() == m
    assert xpu.m % 8 in (0, m % 8)


@settings(max_examples=50, deadline=None)
@given(m=st.integers(16, 5000), n=st.integers(16, 3000),
       k=st.integers(16, 3000),
       shares=st.lists(st.floats(0.01, 1.0), min_size=1, max_size=4))
def test_ops_to_mnk_property(m, n, k, shares):
    total = float(m) * n * k
    ops = [s / sum(shares) * total for s in shares]
    devs = [_mk(f"d{i}", 1.0 + i, align=1) for i in range(len(shares))]
    plan = ops_to_mnk(devs, ops, m, n, k, decompose=False)
    assert plan.total_rows() == m
    assert all(a.m >= 0 for a in plan.assignments)


def test_decompose_square_covers_slice():
    tiles = decompose_square(1000, 2000, 500)
    # tiles must exactly cover the (1000 x 2000) A-slice area
    area = sum(t.m * t.k for t in tiles)
    assert area == 1000 * 2000
    # k' divides k
    kps = {t.k for t in tiles if t.k0 + t.k < 2000 or 2000 % t.k == 0}
    assert kps


def test_decompose_square_prefers_square():
    tiles = decompose_square(2000, 2000, 2000)
    m0, k0 = tiles[0].m, tiles[0].k
    assert 0.45 <= m0 / k0 <= 2.2  # near-square leading tile


def test_squareness_heuristic():
    # perfectly square beats skinny at equal volume
    assert squareness([100], [100], 10) > squareness([1000], [10], 10)


# --------------------------------------------------------------- schedule --

def test_priority_order_fastest_first():
    devs = paper_mach1()
    order = priority_order(devs)
    assert devs[order[0]].kind == "xpu"
    assert devs[order[-1]].kind == "cpu"


def test_timeline_bus_serialization():
    devs = paper_mach2()
    r = solve_bisection(devs, 27e12, n=30000, k=30000, bus="serialized")
    tl = simulate_timeline(devs, r.ops, 30000, 30000)
    copies = sorted([e for e in tl.events if e.kind == "copy_in"],
                    key=lambda e: e.start)
    # no two bus transfers overlap
    for a, b in zip(copies, copies[1:]):
        assert b.start >= a.end - 1e-12
    # priority: xpu (fastest) copies first
    assert copies[0].device == "2080ti-tensor"


def test_timeline_compute_after_copy_in():
    devs = paper_mach2()
    r = solve_bisection(devs, 27e12, n=30000, k=30000, bus="serialized")
    tl = simulate_timeline(devs, r.ops, 30000, 30000)
    for d in devs:
        evs = {e.kind: e for e in tl.device_events(d.name)}
        if "copy_in" in evs and "compute" in evs:
            assert evs["compute"].start >= evs["copy_in"].end - 1e-12


def test_static_scheduler_end_to_end():
    sched = StaticScheduler(paper_mach1())
    s = sched.plan(27e12, n=30000, k=30000)
    assert s.timeline.makespan > 0
    assert sum(s.result.ops) == pytest.approx(27e12, rel=1e-9)


def test_dynamic_scheduler_adapts_to_straggler():
    devs = [_mk("a", 10.0), _mk("b", 10.0)]
    dyn = DynamicScheduler(devs, bus="independent")
    n = k = 4000
    plan0 = dyn.plan(1e13, n=n, k=k)
    share0 = plan0.result.shares()
    assert share0[0] == pytest.approx(0.5, abs=0.05)
    # device b suddenly runs 4x slower (straggler): feed observations
    for ops in (1e12, 2e12, 3e12):
        dyn.observe(0, ops, devs[0].compute(ops))
        dyn.observe(1, ops, devs[1].compute(ops) * 4.0)
    plan1 = dyn.plan(1e13, n=n, k=k)
    share1 = plan1.result.shares()
    assert share1[0] > 0.70  # healthy device now gets the bulk
    assert plan1.result.makespan < plan0.result.makespan * 4.0


# ----------------------------------------------------------------- hgemms --

def test_hgemms_correctness_small():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 96)).astype(np.float32)
    b = rng.standard_normal((96, 128)).astype(np.float32)
    hg = HGemms(paper_mach1())
    c, rep = hg.execute(a, b)
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    assert rep.simulated_makespan > 0


def test_hgemms_speedup_over_standalone():
    hg = HGemms(paper_mach2())
    m = n = k = 2048  # numerics small; timing model from ops regardless
    plan = hg.plan(30000, 30000, 30000)
    mk = plan.schedule.timeline.makespan
    xpu_alone = hg.devices[2].total_time(27e12, 30000, 30000)
    assert mk < xpu_alone  # co-execution beats the best single device
    speedup = xpu_alone / mk
    assert 1.1 < speedup < 1.8  # paper: up to 1.45x on mach2


def test_hgemms_work_distribution_matches_paper():
    """Table 6: mach1 ≈ 0.3% CPU / 21-27% GPU / 73-80% XPU."""
    hg = HGemms(paper_mach1())
    plan = hg.plan(30000, 30000, 30000)
    shares = [a.ops for a in plan.adapted.assignments]
    shares = [s / sum(shares) for s in shares]
    assert shares[0] < 0.02          # CPU
    assert 0.15 < shares[1] < 0.32   # GPU
    assert 0.68 < shares[2] < 0.85   # XPU


def test_hgemms_prediction_errors_low():
    hg = HGemms(paper_mach2())
    errs = hg.prediction_errors(30000, 30000, 30000, noise=0.03)
    for dev, e in errs.items():
        assert e["global"] < 15.0, (dev, e)


def test_workload_total_ops():
    w = GemmWorkload(30000, 30000, 30000)
    assert w.total_ops() == 27e12
