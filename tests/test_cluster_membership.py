"""Device-to-cluster tests (DESIGN.md §16): the pluggable makespan/energy
objective, elastic membership change-points, the device-loss rescue path,
the hetero train-step domain round-trip, and the fault-tolerant runner's
clean handling of an exhausted batch stream."""
import math

import jax.numpy as jnp
import pytest

from repro.core import (BusTopology, MAKESPAN_OBJECTIVE, Objective,
                        TaskGraphDomain, divisible_energy, graph_energy,
                        solve_bisection, solve_hierarchical,
                        solve_list_schedule)
from repro.core.device_model import CopyModel, DeviceProfile, LinearTimeModel
from repro.core.graph import (TaskGraph, TaskNode, transformer_stack,
                              verify_graph_dependencies)
from repro.core.runtime import CoExecutionRuntime, truth_from_profiles
from repro.distributed.hetero import (HeteroBatchScheduler, PodProfile,
                                      TrainStepDomain, TrainStepWorkload)


def _dev(name, tflops, *, idle_w=0.0, jpo=0.0, copy_bw=15.75e9):
    return DeviceProfile(name, "gpu",
                         LinearTimeModel(2.0 / (tflops * 1e12), 1e-6),
                         CopyModel(copy_bw, dtype_size=2),
                         idle_watts=idle_w, joules_per_op=jpo)


def _stack(**kw):
    return [_dev("h0.a", 40.0, **kw), _dev("h0.b", 30.0, **kw),
            _dev("h1.a", 40.0, **kw)]


def _cluster_topo(devs, nic=2e9):
    return BusTopology.cluster({"h0": devs[:2], "h1": devs[2:]},
                               nic_bandwidth_bytes_per_s=nic,
                               nic_latency_s=1e-5)


def _chains(n_chains, n_stages, ops=5e9, nbytes=1e5):
    nodes, edges = [], []
    for c in range(n_chains):
        for s in range(n_stages):
            nodes.append(TaskNode(f"c{c}.s{s}", ops, nbytes, nbytes))
            if s:
                edges.append((f"c{c}.s{s - 1}", f"c{c}.s{s}"))
    return TaskGraph(tuple(nodes), tuple(edges))


# ------------------------------------------------------------ objective --


def test_makespan_objective_bit_identical_list_schedule():
    """Pure-makespan knob: identical selections to the no-objective path
    (acceptance bit-identity contract), energy reported on the side."""
    devs = _stack(idle_w=1.0, jpo=1e-10)
    topo = _cluster_topo(devs)
    g = _chains(4, 3)
    tasks, edges = g.task_specs(), g.edge_indices()
    base = solve_list_schedule(devs, tasks, edges, bus=topo)
    for obj in (MAKESPAN_OBJECTIVE, Objective(energy_weight=0.0)):
        r = solve_list_schedule(devs, tasks, edges, bus=topo, objective=obj)
        assert list(r.assign) == list(base.assign)
        assert list(r.order) == list(base.order)
        assert r.makespan == base.makespan
        assert r.task_finish == base.task_finish
        assert r.energy_j is not None
    assert base.energy_j is None


def test_makespan_objective_bit_identical_hierarchical():
    devs = _stack(idle_w=1.0, jpo=1e-10)
    g = transformer_stack(config="stablelm-12b", layers=4, microbatches=4,
                          groups=4)
    part = g.template_partition(min_repeats=4)
    assert part is not None
    tasks, edges = g.task_specs(), g.edge_indices()
    # separate cache instances: the makespan path must not read entries
    # keyed without the weight, nor vice versa
    from repro.core.optimize import TemplatePlanCache
    base = solve_hierarchical(devs, tasks, edges, partition=part,
                              bus="serialized",
                              template_cache=TemplatePlanCache())
    r = solve_hierarchical(devs, tasks, edges, partition=part,
                           bus="serialized",
                           template_cache=TemplatePlanCache(),
                           objective=MAKESPAN_OBJECTIVE)
    assert list(r.assign) == list(base.assign)
    assert r.makespan == base.makespan
    assert r.energy_j is not None and base.energy_j is None


def test_makespan_objective_bit_identical_bisection():
    devs = _stack(idle_w=1.0, jpo=1e-10)
    base = solve_bisection(devs, 100e12, n=30000, k=30000)
    r = solve_bisection(devs, 100e12, n=30000, k=30000,
                        objective=MAKESPAN_OBJECTIVE)
    assert r.ops == base.ops
    assert r.makespan == base.makespan


def test_energy_weight_trades_makespan_for_joules():
    """A positive exchange rate moves work to the efficient device: energy
    falls, makespan rises — and the sweep is monotone at the optimum."""
    devs = [_dev("fast", 40.0, idle_w=2.0, jpo=4e-10),
            _dev("eff", 10.0, idle_w=1.0, jpo=0.5e-10)]
    g = _chains(1, 4)
    tasks, edges = g.task_specs(), g.edge_indices()
    pts = []
    for w in (0.0, 1e-4, 1e-2):
        r = solve_list_schedule(devs, tasks, edges, bus="independent",
                                objective=Objective(w),
                                exhaustive_limit=4096, max_evals=4097)
        pts.append((r.makespan, r.energy_j))
    for (m0, e0), (m1, e1) in zip(pts, pts[1:]):
        assert m1 >= m0 - 1e-12
        assert e1 <= e0 + 1e-12
    assert pts[-1][1] < pts[0][1]   # the knob actually moved work


def test_energy_accounting_matches_hand_computation():
    d0 = _dev("a", 40.0, idle_w=10.0, jpo=2e-10)
    d1 = _dev("b", 40.0, idle_w=4.0, jpo=1e-10)
    ops = [8e9, 0.0]
    ms = d0.compute(8e9)
    e = divisible_energy([d0, d1], ops, ms)
    busy = d0.compute(8e9)
    assert e == pytest.approx(2e-10 * 8e9 + 10.0 * (ms - busy) + 4.0 * ms)


def test_banned_devices_never_take_free_tasks():
    devs = _stack()
    g = _chains(3, 3)
    tasks, edges = g.task_specs(), g.edge_indices()
    r = solve_list_schedule(devs, tasks, edges, bus="independent",
                            banned=frozenset({2}))
    assert all(j != 2 for j in r.assign)
    assert math.isfinite(r.makespan)


# ----------------------------------------------------------- membership --


def _loss_runtime(truth=None):
    devs = _stack()
    dom = TaskGraphDomain(devs, bus=_cluster_topo(devs), dynamic=True)
    return CoExecutionRuntime(dom, executor="virtual", truth=truth,
                              feedback=False, max_inflight=1)


def test_device_leave_rescues_inflight_job():
    g = _chains(6, 4)
    with _loss_runtime() as rt:
        job = rt.submit(g)
        job.wait(60)
        before = job.measured.makespan
        at = 0.3 * before
        recs = rt.device_leave("h1.a", at=at)
        assert len(recs) == 1
        rec = recs[0]
        assert rec.reason == "device-loss"
        assert rec.straggler == "h1.a"
        assert rec.spliced   # the frontier touched the departed device
        after = job.measured.makespan
        assert math.isfinite(after)
        # splice keeps every DAG dependency intact
        assert not verify_graph_dependencies(rec.spec, job.measured)
        # no re-solved task lands on the departed device
        spliced = set(rec.spliced)
        assert not [e.task for e in job.measured.events
                    if e.task in spliced and e.device == "h1.a"]
        # future admissions plan without it
        job2 = rt.submit(g)
        job2.wait(60)
        assert all(e.device != "h1.a" for e in job2.measured.events)


def test_device_leave_then_join_restores_planning_set():
    g = _chains(6, 4)
    with _loss_runtime() as rt:
        job = rt.submit(g)
        job.wait(60)
        rt.device_leave("h1.a", at=0.3 * job.measured.makespan)
        assert [d.name for d in rt.domain.predict()] == ["h0.a", "h0.b"]
        devs = _stack()
        rt.device_join(devs[2], topology=_cluster_topo(devs))
        assert [d.name for d in rt.domain.predict()] == \
            ["h0.a", "h0.b", "h1.a"]


def test_device_leave_last_device_refused():
    devs = [_dev("only", 40.0)]
    dom = TaskGraphDomain(devs, bus="independent", dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual",
                            max_inflight=1) as rt:
        with pytest.raises(ValueError):
            rt.device_leave("only")


def test_device_loss_rescue_beats_locked_in():
    """The BENCH_cluster scenario in miniature: ground truth runs h1.a
    50x slow; the rescue must beat riding the stale plan."""
    dead = 50.0
    truth = truth_from_profiles(
        _stack(), lambda uid, name: dead if name == "h1.a" else 1.0)
    g = _chains(6, 4)
    with _loss_runtime(truth) as rt:
        job = rt.submit(g)
        job.wait(60)
        locked = job.measured.makespan
    with _loss_runtime(truth) as rt:
        job = rt.submit(g)
        job.wait(60)
        planned = job.plan.schedule.timeline.makespan
        recs = rt.device_leave("h1.a", at=0.25 * planned)
        assert recs
        assert job.measured.makespan < locked / 1.10


def test_dynamic_scheduler_set_devices_carries_fitted_models():
    from repro.core.schedule import DynamicScheduler
    devs = _stack()
    dyn = DynamicScheduler(devs, bus="independent")
    # re-fit h0.b 2x slow from observations
    for _ in range(3):
        dyn.observe(1, 1e12, 2.0 * devs[1].compute(1e12))
    slow = dyn.snapshot()[1]
    assert slow.compute(1e12) > 1.5 * devs[1].compute(1e12)
    epoch = dyn.epoch
    dyn.set_devices([devs[0], devs[1]])   # h1.a departs
    assert [d.name for d in dyn.snapshot()] == ["h0.a", "h0.b"]
    # the survivor kept its re-fitted model, not the stale profile
    assert dyn.snapshot()[1].compute(1e12) == slow.compute(1e12)
    assert dyn.epoch == epoch + 1


# --------------------------------------------- hetero train-step domain --


PODS = [PodProfile("pod0", chips=256, peak_flops=197e12, grain=16),
        PodProfile("pod1", chips=128, peak_flops=197e12, grain=16)]


def test_train_step_domain_optimize_adapt_roundtrip():
    dom = TrainStepDomain(PODS, flops_per_token=6 * 12e9, seq_len=4096,
                          dynamic=False)
    w = TrainStepWorkload(global_batch=384, seq_len=4096)
    devices = list(dom.predict())
    opt = dom.optimize(devices, w)
    split = dom.adapt(devices, opt, w)
    assert sum(split.sizes) == 384
    assert all(s % 16 == 0 for s in split.sizes)
    assert split.sizes[0] > split.sizes[1]   # twice the chips, more rows
    # predicted step time is the slowest pod's compute at its share
    assert split.predicted_step_s == pytest.approx(
        max(d.compute(s * 4096) for d, s in zip(devices, split.sizes)
            if s > 0))
    sched = dom.schedule(devices, split, w)
    assert sched.timeline.makespan >= split.predicted_step_s - 1e-12


def test_feed_step_routes_measurements_by_pod_name():
    s = HeteroBatchScheduler(PODS, flops_per_token=6 * 12e9, seq_len=4096,
                             dynamic=True)
    split = s.plan(384)
    epoch0 = s.dyn.epoch
    # mapping form: pod name -> measured step seconds (pod1 3x slow)
    base = {p.name: d.compute(r * 4096)
            for p, d, r in zip(s.pods, s.devices, split.sizes)}
    for step in range(3):
        fed = s.feed_step(split, {
            "pod0": base["pod0"],
            "pod1": 3.0 * base["pod1"] * (1 + 0.01 * step)})
        assert fed == 2
    assert s.dyn.epoch > epoch0
    split2 = s.plan(384)
    assert split2.sizes[1] < split.sizes[1]   # straggler sheds load

    # timeline form routes through the same pump
    from repro.core.bus import BusEvent, Timeline
    tl = Timeline([BusEvent(device="pod0", kind="compute", start=0.0,
                            end=base["pod0"])])
    assert s.feed_step(split, tl) == 1
    # unknown pods / zero shares are ignored, not mis-routed
    assert s.feed_step(split, {"ghost": 1.0}) == 0


def test_pod_leave_and_join_are_change_points():
    s = HeteroBatchScheduler(PODS, flops_per_token=6 * 12e9, seq_len=4096,
                             dynamic=True)
    s.plan(384)
    s.pod_leave("pod1")
    assert [p.name for p in s.pods] == ["pod0"]
    split = s.plan(384)
    assert split.sizes == (384,)
    s.pod_join(PODS[1])
    split = s.plan(384)
    assert len(split.sizes) == 2 and sum(split.sizes) == 384
    # the pump re-keyed: observations route to the rebuilt indices
    assert s.feed_step(split, {"pod1": 0.5}) == 1
    with pytest.raises(ValueError):
        s.pod_leave("pod0"), s.pod_leave("pod1")


# --------------------------------------------------- elastic runner fix --


def test_runner_stops_cleanly_on_exhausted_stream(tmp_path):
    """A batch stream shorter than num_steps must end the run with a final
    checkpoint, not leak StopIteration out of ``run`` (PEP 479 makes that
    a RuntimeError inside generators upstream)."""
    from repro.checkpoint import store
    from repro.distributed.elastic import FaultTolerantRunner, RunnerConfig

    def step(state, batch):
        return {"x": state["x"] + 1.0}, {}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=100)
    runner = FaultTolerantRunner(cfg, step_fn=step, state={"x": jnp.asarray(0.0)})
    final = runner.run(({} for _ in range(3)), num_steps=10)
    assert runner.step == 3          # stopped at exhaustion, no exception
    assert float(final["x"]) == 3.0
    assert store.latest_step(tmp_path) == 3   # forced final checkpoint


def test_remesh_routes_membership_through_scheduler(tmp_path):
    from repro.distributed.elastic import FaultTolerantRunner, RunnerConfig

    def step(state, batch):
        return {"x": state["x"] + 1.0}, {}

    cfg = RunnerConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2)
    runner = FaultTolerantRunner(cfg, step_fn=step, state={"x": jnp.asarray(0.0)})
    runner.run(({} for _ in range(4)), num_steps=4)
    s = HeteroBatchScheduler(PODS, flops_per_token=6 * 12e9, seq_len=4096)
    runner.remesh(None, scheduler=s, lost=("pod1",))
    assert [p.name for p in s.pods] == ["pod0"]
    assert runner.step == 4          # state restored at the same step
    runner.remesh(None, scheduler=s, joined=(PODS[1],))
    assert [p.name for p in s.pods] == ["pod0", "pod1"]
