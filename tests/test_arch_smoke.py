"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs.  Also a decode-vs-prefill
consistency check per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_tiny_config
from repro.models import Model

B, S = 2, 16


def _batch(cfg, key):
    kt, kl, ke = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(ke, (B, S, cfg.d_model),
                                            jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


# One representative per family (dense / MoE / SSM / VLM frontend) runs in
# the default suite; the full arch sweep runs under -m slow.
_FAST_ARCHS = {"stablelm-12b", "dbrx-132b", "mamba2-2_7b", "internvl2-26b"}
_ARCH_PARAMS = [a if a in _FAST_ARCHS
                else pytest.param(a, marks=pytest.mark.slow)
                for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_forward_and_train_step(arch, rng):
    cfg = get_tiny_config(arch)
    model = Model(cfg)
    params = model.init(rng)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = jax.jit(model.logits)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    def loss_fn(p):
        return model.loss(p, batch)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # loss should be near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"
    # at least one non-zero grad per major param group
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert gnorm > 0


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_decode_step_shapes(arch, rng):
    cfg = get_tiny_config(arch)
    model = Model(cfg)
    params = model.init(rng)
    cache = model.init_cache(batch=B, max_len=S + 4)
    if cfg.frontend != "none":
        step = {"embeds": jax.random.normal(rng, (B, 1, cfg.d_model)) * 0.02}
    else:
        step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache = jax.jit(model.decode_step)(params, cache, step)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 1
    logits2, cache = jax.jit(model.decode_step)(params, cache, step)
    assert int(cache["pos"]) == 2
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", [
    "stablelm-12b", "mamba2-2_7b", "dbrx-132b",
    pytest.param("minicpm3-4b", marks=pytest.mark.slow),
    pytest.param("hymba-1_5b", marks=pytest.mark.slow),
])
def test_decode_matches_teacher_forcing(arch, rng):
    """Greedy decode logits must match full-sequence logits position-wise."""
    cfg = get_tiny_config(arch)
    model = Model(cfg)
    params = model.init(rng)
    T = 8
    if cfg.frontend != "none":
        pytest.skip("embeds-input archs covered by shape test")
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0,
                                cfg.vocab_size)
    full = model.logits(params, {"tokens": tokens})
    cache = model.init_cache(batch=B, max_len=T)
    step_fn = jax.jit(model.decode_step)
    for t in range(T):
        logits, cache = step_fn(params, cache, {"tokens": tokens[:, t:t + 1]})
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode diverges from prefill at t={t}")


def test_moe_interleave_structure():
    cfg = get_tiny_config("llama4-maverick-400b-a17b")
    assert cfg.moe_every == 2
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "s0" in params["layers"] and "s1" in params["layers"]
    assert "moe" in params["layers"]["s1"]
    assert "mlp" in params["layers"]["s0"]
