"""Template tiling — the exactness property (DESIGN.md §15).

Whatever assignment ``solve_hierarchical`` stitches (random block
shapes, random repeat counts, boundary fan-in, the seam-descent polish
on top), the finish times it reports must be *byte-identical* to the
engine's from-scratch simulation of that assignment: tiling is a
placement strategy, never a pricing approximation.
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (BusTopology, CopyModel, DeviceProfile,
                        LinearTimeModel, NO_COPY, TaskGraph, TaskNode,
                        TemplatePlanCache, graph_finish_times,
                        solve_hierarchical)


def _devs():
    return [
        DeviceProfile("cpu", "cpu", LinearTimeModel(a=1 / 5e12, b=1e-4),
                      NO_COPY),
        DeviceProfile("gpu0", "gpu", LinearTimeModel(a=1 / 60e12, b=5e-5),
                      CopyModel(16e9, dtype_size=4)),
        DeviceProfile("gpu1", "gpu", LinearTimeModel(a=1 / 25e12, b=8e-5),
                      CopyModel(8e9, dtype_size=4)),
    ]


_bytes = st.one_of(st.just(0.0), st.floats(1e3, 1e8))


@st.composite
def _tiled_graph(draw):
    """R repeats of one random block, chained tail→head, with builder
    ``blocks`` metadata (zero byte counts mixed in so the free
    same-device / no-output fast paths are exercised)."""
    k = draw(st.integers(2, 5))
    block_edges = tuple((u, v) for u in range(k) for v in range(u + 1, k)
                        if draw(st.booleans()))
    costs = [(draw(st.floats(1e8, 1e12)), draw(_bytes), draw(_bytes))
             for _ in range(k)]
    repeats = draw(st.integers(4, 7))
    nodes, edges, blocks = [], [], []
    for r in range(repeats):
        names = [f"b{r}.n{i}" for i in range(k)]
        for i, (ops, inb, outb) in enumerate(costs):
            nodes.append(TaskNode(names[i], ops=ops, in_bytes=inb,
                                  out_bytes=outb))
        edges.extend((names[u], names[v]) for u, v in block_edges)
        if r > 0:
            edges.append((f"b{r-1}.n{k-1}", names[0]))
        blocks.append(tuple(names))
    return TaskGraph(nodes=tuple(nodes), edges=tuple(edges),
                     blocks=tuple(blocks))


@settings(max_examples=30, deadline=None)
@given(g=_tiled_graph())
def test_tiled_finish_times_equal_from_scratch_simulation(g):
    devs = _devs()
    part = g.template_partition(min_repeats=2)
    assert part is not None
    r = solve_hierarchical(devs, g.task_specs(), g.edge_indices(),
                           partition=part,
                           template_cache=TemplatePlanCache())
    truth = graph_finish_times(
        devs, g.task_specs(), g.edge_indices(), r.assign,
        topology=BusTopology.from_spec("serialized", devs), order=r.order)
    assert r.task_finish == truth
    assert r.makespan == max(truth)
    assert len({a for a in r.assign}) >= 1 and all(
        0 <= a < len(devs) for a in r.assign)
