"""Hypothesis property tests on system-level invariants."""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (CopyModel, DeviceProfile, LinearTimeModel, NO_COPY,
                        simulate_timeline, solve_bisection)
from repro.core.adapt import decompose_square
from repro.data.pipeline import DataConfig, SyntheticLM


def _devs(tflops_list, bw=16e9):
    out = []
    for i, tf in enumerate(tflops_list):
        ops = tf * 1e12 / 2
        copy = NO_COPY if i == 0 else CopyModel(bw, dtype_size=4)
        out.append(DeviceProfile(f"d{i}", "cpu" if i == 0 else "gpu",
                                 LinearTimeModel(a=1 / ops, b=1e-4), copy))
    return out


@settings(max_examples=25, deadline=None)
@given(tfs=st.lists(st.floats(0.5, 80), min_size=2, max_size=4),
       mexp=st.integers(11, 13))
def test_coexecution_never_slower_than_best_device(tfs, mexp):
    """POAS invariant: co-execution makespan <= best standalone device."""
    devs = _devs(tfs)
    n = k = 2 ** mexp
    N = float(n) * n * k
    res = solve_bisection(devs, N, n=n, k=k, bus="serialized")
    best_alone = min(d.total_time(N, n, k) for d in devs)
    assert res.makespan <= best_alone * 1.0001


@settings(max_examples=25, deadline=None)
@given(tfs=st.lists(st.floats(0.5, 50), min_size=2, max_size=4))
def test_timeline_events_well_formed(tfs):
    devs = _devs(tfs)
    n = k = 4096
    res = solve_bisection(devs, float(n) * n * k, n=n, k=k, bus="serialized")
    tl = simulate_timeline(devs, res.ops, n, k)
    # events have non-negative durations and bus transfers never overlap
    xfers = sorted((e for e in tl.events if e.kind != "compute"),
                   key=lambda e: e.start)
    for e in tl.events:
        assert e.end >= e.start >= 0
    for a, b in zip(xfers, xfers[1:]):
        assert b.start >= a.end - 1e-9
    # makespan is the max event end
    assert tl.makespan == pytest.approx(max(e.end for e in tl.events))


@settings(max_examples=30, deadline=None)
@given(m=st.integers(8, 3000), k=st.integers(8, 3000),
       n=st.integers(8, 1000))
def test_decompose_square_tiles_partition_exactly(m, k, n):
    tiles = decompose_square(m, k, n)
    # exact cover: areas sum and no tile escapes the slice
    assert sum(t.m * t.k for t in tiles) == m * k
    cover = np.zeros((min(m, 64), min(k, 64)), dtype=int)
    for t in tiles:
        r0, c0 = min(t.row0, 64), min(t.k0, 64)
        r1, c1 = min(t.row0 + t.m, 64), min(t.k0 + t.k, 64)
        cover[r0:r1, c0:c1] += 1
    assert (cover == 1).all()


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), seed=st.integers(0, 10))
def test_data_stream_replayable_property(step, seed):
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=4, seed=seed)
    a = SyntheticLM(cfg).batch(step)
    b = SyntheticLM(cfg).batch(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert (a["tokens"] < 64).all() and (a["tokens"] >= 0).all()


@settings(max_examples=15, deadline=None)
@given(shares=st.lists(st.floats(0.05, 1.0), min_size=2, max_size=4),
       gb=st.integers(2, 64))
def test_hetero_split_monotone_in_speed(shares, gb):
    """Faster pods never get fewer rows than slower ones."""
    from repro.distributed.hetero import HeteroBatchScheduler, PodProfile
    pods = [PodProfile(f"p{i}", 256, 197e12, derate=s, grain=1)
            for i, s in enumerate(shares)]
    sched = HeteroBatchScheduler(pods, flops_per_token=1e9, seq_len=128,
                                 dynamic=False)
    split = sched.plan(gb)
    assert sum(split.sizes) == gb
    order = np.argsort(shares)
    for slow, fast in zip(order, order[1:]):
        if shares[fast] > shares[slow] * 1.05:  # allow grain-rounding ties
            assert split.sizes[fast] >= split.sizes[slow] - 1
