"""The unified bus timeline engine: topologies, solver/simulator agreement,
chunked pipelined copies, per-link executor ticket order, PlanCache safety.

These are the regression nets for the historical solver/simulator
disagreements: the solver charged no-copy devices for bus queue time they
never wait on, and let output copies overlap input copies on the
supposedly serialized bus.  Both are now impossible by construction — the
solver's ``_finish_times`` and ``simulate_timeline`` are the same engine —
and the tests here pin that equivalence for random device sets, priority
orders, and chunk counts.
"""
import math
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # collection must never hard-error
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed "
            "(pip install -r requirements-dev.txt)")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # placeholder strategies; only consumed by decorator args
        floats = integers = lists = booleans = permutations = \
            staticmethod(lambda *a, **k: None)

from repro.core import (BusTopology, CopyModel, DeviceProfile, DeviceTask,
                        HGemms, Link, LinearTimeModel, NO_COPY,
                        OverlappedExecutor, PlanCache, build_timeline,
                        engine_finish_times, ops_to_mnk, paper_mach1,
                        paper_mach2, priority_order, simulate_timeline,
                        solve_analytic, solve_bisection, with_pipeline)
from repro.core.optimize import _finish_times


def _mk(name, tflops, bw=None, align=1, b=1e-4, chunks=1):
    ops_per_s = tflops * 1e12 / 2
    copy = NO_COPY if bw is None else CopyModel(bw, dtype_size=4)
    return DeviceProfile(name, "gpu" if bw else "cpu",
                         LinearTimeModel(a=1 / ops_per_s, b=b), copy,
                         align_m=align, pipeline_chunks=chunks)


# -------------------------------------------------------------- topologies --

def test_serialized_topology_single_link():
    devs = paper_mach1()
    topo = BusTopology.serialized(devs)
    assert len(topo.links) == 1
    assert topo.is_contended()
    # the NO_COPY CPU is attached to no link at all
    assert topo.link_of("xeon-e5", "copy_in") is None
    assert topo.link_of("2080ti-cuda", "copy_in").name == "pcie"
    assert topo.link_of("2080ti-cuda", "copy_out").name == "pcie"


def test_independent_topology_private_links():
    devs = paper_mach1()
    topo = BusTopology.independent(devs)
    assert not topo.is_contended()
    gpu = topo.link_of("2080ti-cuda", "copy_in")
    xpu = topo.link_of("2080ti-tensor", "copy_in")
    assert gpu.name != xpu.name


def test_custom_mixed_topology():
    """CPU no-copy + two GPUs sharing PCIe + a TPU group on its own ICI."""
    devs = [_mk("cpu", 1.0), _mk("gpu0", 10.0, bw=16e9),
            _mk("gpu1", 12.0, bw=16e9), _mk("tpu", 40.0, bw=50e9)]
    topo = BusTopology.custom(
        ["pcie", Link("ici", bandwidth_bytes_per_s=45e9)],
        {"cpu": None, "gpu0": "pcie", "gpu1": "pcie", "tpu": "ici"})
    assert topo.is_contended()
    assert topo.link_of("tpu", "copy_in").bandwidth_bytes_per_s == 45e9
    tl = build_timeline(devs, [1e11] * 4, 4000, 4000, topology=topo)
    # GPU copies serialize with each other, not with the TPU's ICI feed
    pcie = tl.link_events("pcie")
    ici = tl.link_events("ici")
    assert {e.device for e in pcie} == {"gpu0", "gpu1"}
    assert {e.device for e in ici} == {"tpu"}
    for a, b in zip(pcie, pcie[1:]):
        assert b.start >= a.end - 1e-12
    # the ICI link's bandwidth cap slows the TPU below its own copy model
    t_in = next(e for e in ici if e.kind == "copy_in")
    assert t_in.duration > devs[3].copy.in_time(1e11, 4000, 4000) - 1e-15
    # CPU computes from t=0 — attached to nothing
    cpu = tl.device_events("cpu")
    assert cpu[0].kind == "compute" and cpu[0].start == 0.0


def test_from_spec_rejects_unknown():
    with pytest.raises(ValueError, match="unknown bus spec"):
        BusTopology.from_spec("warp-drive", paper_mach1())


def test_topology_rejects_unknown_link():
    with pytest.raises(ValueError, match="unknown link"):
        BusTopology.custom(["pcie"], {"gpu0": "nvlink"})


# ------------------------------------------- solver/simulator agreement -----

AGREEMENT_MATRIX = [
    ("mach1", paper_mach1, "serialized"),
    ("mach1", paper_mach1, "independent"),
    ("mach2", paper_mach2, "serialized"),
    ("mach2", paper_mach2, "independent"),
]


@pytest.mark.parametrize("name,mk,bus", AGREEMENT_MATRIX,
                         ids=[f"{m}-{b}" for m, _, b in AGREEMENT_MATRIX])
def test_solver_simulator_agreement(name, mk, bus):
    """Acceptance: for every device set (incl. the NO_COPY CPU),
    ``max(_finish_times(...)) == simulate_timeline(...).makespan`` to 1e-9
    relative — the solver optimizes exactly what the simulator reports."""
    devs = mk()
    r = solve_bisection(devs, 27e12, n=30000, k=30000, bus=bus)
    tl = simulate_timeline(devs, r.ops, 30000, 30000, topology=bus)
    fin = _finish_times(devs, r.ops, 30000, 30000, bus)
    assert max(fin) == pytest.approx(tl.makespan, rel=1e-9)
    for d, f in zip(devs, fin):
        assert f == pytest.approx(tl.device_finish(d.name), rel=1e-9, abs=0.0)


def test_no_copy_device_not_charged_for_bus_time():
    """Regression: the solver predicted the mach1 CPU finishing ~9.24 ms
    (charged for GPU/XPU copies queued on a bus it never touches) where the
    simulator said ~0.65 ms.  A no-copy device's finish is exactly its
    compute time."""
    devs = paper_mach1()
    r = solve_bisection(devs, 27e12, n=30000, k=30000, bus="serialized")
    fin = _finish_times(devs, r.ops, 30000, 30000, "serialized")
    cpu = devs[0]
    assert math.isinf(cpu.copy.bandwidth_bytes_per_s)
    assert fin[0] == pytest.approx(cpu.compute(r.ops[0]), rel=1e-12)
    tl = simulate_timeline(devs, r.ops, 30000, 30000)
    assert tl.device_events(cpu.name)[0].start == 0.0


def test_output_copies_never_overlap_input_copies():
    """Regression: the solver reset the output-copy clock to 0, letting C
    copies overlap A/B copies on the serialized bus (GPU finish 9.24 ms
    solver vs 10.80 ms simulator).  On any one link, transfers in either
    direction must never overlap."""
    devs = paper_mach2()
    r = solve_bisection(devs, 27e12, n=30000, k=30000, bus="serialized")
    tl = simulate_timeline(devs, r.ops, 30000, 30000)
    xfers = sorted((e for e in tl.events if e.kind != "compute"),
                   key=lambda e: e.start)
    for a, b in zip(xfers, xfers[1:]):
        assert b.start >= a.end - 1e-12, (a, b)
    # and the solver's finish equals the simulator's for every device
    fin = _finish_times(devs, r.ops, 30000, 30000, "serialized")
    for d, f in zip(devs, fin):
        assert f == pytest.approx(tl.device_finish(d.name), rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(tfs=st.lists(st.floats(0.2, 60), min_size=1, max_size=4),
       copies=st.lists(st.booleans(), min_size=4, max_size=4),
       shares=st.lists(st.floats(0.0, 1.0), min_size=4, max_size=4),
       seed=st.integers(0, 2 ** 31), chunked=st.booleans(),
       serialized=st.booleans())
def test_engine_equals_simulator_property(tfs, copies, shares, seed,
                                          chunked, serialized):
    """Property (the regression net for bugs 1-2): the unified engine's
    finish times equal ``simulate_timeline``'s per-device finishes for
    random device sets including NO_COPY devices, random op splits, random
    priority orders, and random chunk counts."""
    rng = np.random.default_rng(seed)
    devs = [_mk(f"d{i}", tf, bw=None if not copies[i] else 12e9,
                chunks=int(rng.integers(1, 5)) if chunked else 1)
            for i, tf in enumerate(tfs)]
    n = k = 2048
    total = 16e9
    s = sum(shares[:len(devs)]) or 1.0
    ops = [x / s * total for x in shares[:len(devs)]]
    order = list(rng.permutation(len(devs)))
    bus = "serialized" if serialized else "independent"
    fin = _finish_times(devs, ops, n, k, bus, order)
    tl = simulate_timeline(devs, ops, n, k, topology=bus, order=order)
    for d, f in zip(devs, fin):
        assert f == pytest.approx(tl.device_finish(d.name), rel=1e-9,
                                  abs=1e-15)
    assert max(fin, default=0.0) == pytest.approx(tl.makespan, rel=1e-9,
                                                  abs=1e-15)


# ------------------------------------------------- chunked pipelining -------

def test_chunks_of_one_match_legacy_timeline():
    devs = paper_mach2()
    r = solve_bisection(devs, 27e12, n=30000, k=30000, bus="serialized")
    a = simulate_timeline(devs, r.ops, 30000, 30000)
    b = simulate_timeline(devs, r.ops, 30000, 30000,
                          chunks=[1] * len(devs))
    assert [(e.device, e.kind, e.start, e.end) for e in a.events] == \
        [(e.device, e.kind, e.start, e.end) for e in b.events]


def test_chunked_events_well_formed():
    devs = with_pipeline(paper_mach1(), 4)
    ops = [0.0, 3e10, 4e10]
    tl = simulate_timeline(devs, ops, 4096, 4096)
    for name in ("2080ti-cuda", "2080ti-tensor"):
        evs = tl.device_events(name)
        ins = sorted((e for e in evs if e.kind == "copy_in"),
                     key=lambda e: e.chunk)
        comps = sorted((e for e in evs if e.kind == "compute"),
                       key=lambda e: e.chunk)
        outs = sorted((e for e in evs if e.kind == "copy_out"),
                      key=lambda e: e.chunk)
        assert len(ins) == len(comps) == len(outs) == 4
        for j in range(4):
            # chunk j computes only after its slice landed, copies out only
            # after its compute — the pipelined overlap invariant
            assert comps[j].start >= ins[j].end - 1e-12
            assert outs[j].start >= comps[j].end - 1e-12
        # the first input chunk carries the shared B panel: it is longest
        assert ins[0].duration > ins[1].duration
    # per-link serialization still holds with chunked transfers
    xfers = sorted((e for e in tl.events if e.kind != "compute"),
                   key=lambda e: e.start)
    for a, b in zip(xfers, xfers[1:]):
        assert b.start >= a.end - 1e-12


def test_pipelining_reduces_makespan_mach1():
    """Acceptance: chunked pipelined copies shorten the simulated
    paper_mach1 4096^3 GEMM critical path vs the unpipelined plan."""
    m = n = k = 4096
    N = float(m) * n * k
    base = solve_bisection(paper_mach1(), N, n=n, k=k, bus="serialized")
    t0 = simulate_timeline(paper_mach1(), base.ops, n, k).makespan
    piped = with_pipeline(paper_mach1(), 4)
    r = solve_bisection(piped, N, n=n, k=k, bus="serialized")
    t1 = simulate_timeline(piped, r.ops, n, k).makespan
    assert t1 < t0 * 0.95
    # and the solver priced the pipelined timeline exactly
    assert r.makespan == pytest.approx(t1, rel=1e-9)


def test_chunked_copies_pay_latency_per_transfer():
    """Each chunk is a separate DMA: chunks past the first pay the copy
    launch latency again, so latency-bearing profiles can't chunk for
    free."""
    lat = 2e-4
    dev = DeviceProfile(
        "gpu", "gpu", LinearTimeModel(a=1e-13, b=0.0),
        CopyModel(16e9, dtype_size=4, latency_s=lat))
    c, n, k = 1e10, 2048, 2048
    t1 = build_timeline([dev], [c], n, k, chunks=[1])
    t4 = build_timeline([dev], [c], n, k, chunks=[4])
    in1 = sum(e.duration for e in t1.events if e.kind == "copy_in")
    in4 = sum(e.duration for e in t4.events if e.kind == "copy_in")
    assert in4 == pytest.approx(in1 + 3 * lat, rel=1e-9)


def test_solver_prices_chunk_overhead():
    """Over-chunking is not free: each chunk pays the compute model's
    launch intercept, so the engine's makespan is monotone-increasing in C
    for a no-copy device (nothing to overlap, pure overhead)."""
    dev = [_mk("cpu", 1.0, b=1e-3)]
    ops = [1e9]
    t1 = engine_finish_times(dev, ops, 1000, 1000, chunks=[1])[0]
    t8 = engine_finish_times(dev, ops, 1000, 1000, chunks=[8])[0]
    assert t8 > t1
    assert t8 == pytest.approx(t1 + 7 * 1e-3, rel=1e-6)


def test_schedule_prices_adapted_chunk_counts():
    """The scheduled timeline charges the chunk count adapt actually
    produced, not the nominal pipeline_chunks — a device grain-capped to 2
    chunks must not pay 8 launch intercepts."""
    devs = [_mk("cpu", 0.01),
            _mk("gpu", 10.0, bw=16e9, align=8, chunks=8)]
    hg = HGemms(devs)
    # small m: the GPU slice can only split into a few align-8 chunks
    plan = hg.plan(48, 512, 512)
    gpu_asg = plan.adapted.assignments[1]
    assert gpu_asg.m > 0
    n_chunks = max(1, len(gpu_asg.chunk_rows))
    assert n_chunks < 8
    tl = plan.schedule.timeline
    comps = [e for e in tl.device_events("gpu") if e.kind == "compute"]
    assert len(comps) == n_chunks


def test_pipelined_execution_real_numerics_and_overlap():
    """HGemms really streams the chunks: the co-executed GEMM is exact and
    the measured timeline shows compute chunk 0 finishing before the last
    input chunk was copied (the overlap the plan priced)."""
    devs = with_pipeline(paper_mach1(), 4)
    hg = HGemms(devs)
    rng = np.random.default_rng(3)
    a = rng.standard_normal((512, 128)).astype(np.float32)
    b = rng.standard_normal((128, 256)).astype(np.float32)
    c, rep = hg.execute(a, b)
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    meas = rep.measured
    for name in {e.device for e in meas.events}:
        evs = meas.device_events(name)
        ins = sorted((e for e in evs if e.kind == "copy_in"),
                     key=lambda e: e.chunk)
        comps = sorted((e for e in evs if e.kind == "compute"),
                       key=lambda e: e.chunk)
        outs = sorted((e for e in evs if e.kind == "copy_out"),
                      key=lambda e: e.chunk)
        if len(ins) > 1:
            # chunked device: every compute chunk starts after its own
            # input chunk landed, and outputs follow their computes
            assert len(ins) == len(comps)
            for i_ev, c_ev in zip(ins, comps):
                assert c_ev.start >= i_ev.end - 1e-9
            for c_ev, o_ev in zip(comps, outs):
                assert o_ev.start >= c_ev.end - 1e-9


def test_adapt_maps_chunks_to_row_chunks():
    devs = with_pipeline(paper_mach1(), 4)
    m, n, k = 30000, 4096, 4096
    r = solve_bisection(devs, float(m) * n * k, n=n, k=k, bus="serialized")
    plan = ops_to_mnk(devs, r.ops, m, n, k)
    for d, a in zip(devs, plan.assignments):
        assert sum(a.chunk_rows) == a.m
        if a.m == 0:
            assert a.chunk_rows == ()
            continue
        assert len(a.chunk_rows) <= max(1, d.pipeline_chunks)
        # all but the last chunk land on the device's alignment grain
        for r_j in a.chunk_rows[:-1]:
            assert r_j % max(d.align_m, 1) == 0
        offs = a.chunk_offsets()
        assert offs[0] == a.row0
        assert offs[-1] + a.chunk_rows[-1] == a.row0 + a.m


# --------------------------------------------------- executor ticket order --

def test_executor_matches_engine_per_link_ticket_order():
    """Acceptance: the overlapped executor's measured bus-event order
    matches the engine's per-link ticket order, including on a multi-link
    topology where two links grant concurrently."""
    devs = [_mk("cpu", 1.0), _mk("gpu0", 10.0, bw=16e9),
            _mk("gpu1", 12.0, bw=16e9), _mk("tpu", 40.0, bw=50e9)]
    topo = BusTopology.custom(
        ["pcie", "ici"],
        {"cpu": None, "gpu0": "pcie", "gpu1": "pcie", "tpu": "ici"})
    planned = build_timeline(devs, [5e9, 2e10, 2e10, 5e10], 2048, 2048,
                             topology=topo)
    tickets = planned.link_ticket_order()
    assert set(tickets) == {"pcie", "ici"}

    def nop():
        pass

    tasks = []
    kinds = {(e.device, e.kind) for e in planned.events}
    for d in devs:
        tasks.append(DeviceTask(
            device=d.name,
            copy_in=nop if (d.name, "copy_in") in kinds else None,
            compute=nop,
            copy_out=nop if (d.name, "copy_out") in kinds else None))
    measured = OverlappedExecutor(devs, planned).run(tasks)
    for link, seq in tickets.items():
        got = [(e.device, e.kind) for e in
               sorted((e for e in measured.events if e.link == link),
                      key=lambda e: e.start)]
        assert got == seq
        # per-link serialization of the measured run
        evs = measured.link_events(link)
        for a, b in zip(evs, evs[1:]):
            assert b.start >= a.end - 1e-9


def test_executor_streams_chunks_with_real_overlap():
    """The pipelined task path realizes the overlap the engine prices:
    compute chunk 0 runs while input chunk 1 streams, and output chunk 0
    copies out while later compute chunks are still running."""
    import time as _time
    dev = [_mk("gpu", 10.0, bw=16e9, chunks=3)]
    planned = build_timeline(dev, [1e10], 2048, 2048)

    def sleeper(dt):
        def fn():
            _time.sleep(dt)
        return fn

    task = DeviceTask(
        device="gpu", copy_in=None, compute=None, copy_out=None,
        copy_in_chunks=[sleeper(0.05)] * 3,
        compute_chunks=[sleeper(0.08)] * 3,
        copy_out_chunks=[sleeper(0.01)] * 3)
    measured = OverlappedExecutor(dev, planned).run(task and [task])
    ins = sorted(measured.device_events("gpu"), key=lambda e: e.chunk)
    ins = [e for e in ins if e.kind == "copy_in"]
    comps = sorted((e for e in measured.device_events("gpu")
                    if e.kind == "compute"), key=lambda e: e.chunk)
    outs = sorted((e for e in measured.device_events("gpu")
                   if e.kind == "copy_out"), key=lambda e: e.chunk)
    assert len(ins) == len(comps) == len(outs) == 3
    # compute chunk 0 started before the last input chunk finished
    assert comps[0].start < ins[2].end
    # output chunk 0 finished before the last compute chunk finished
    assert outs[0].end < comps[2].end
    # and each chunk still respects its own dependencies
    for j in range(3):
        assert comps[j].start >= ins[j].end - 1e-9
        assert outs[j].start >= comps[j].end - 1e-9


def test_executor_bus_sequence_collapses_chunks():
    devs = with_pipeline(paper_mach2(), 3)
    r = solve_bisection(devs, 1e12, n=4000, k=4000, bus="serialized")
    planned = simulate_timeline(devs, r.ops, 4000, 4000)
    seq = OverlappedExecutor.bus_sequence(planned)
    assert len(seq) == len(set(seq))  # one ticket per (device, kind)
    # single-bus topology: the flat order IS the per-link order
    assert planned.link_ticket_order() == {"pcie": seq}


# --------------------------------------------------------- plan cache lock --

def test_plan_cache_concurrent_hammering():
    """Regression: PlanCache mutated an OrderedDict with no lock; hammer
    get/put/invalidate from many threads and check it stays coherent."""
    cache = PlanCache(maxsize=32)
    stop = threading.Event()
    errors = []

    def worker(tid):
        try:
            i = 0
            while not stop.is_set():
                key = (tid, i % 64)
                cache.put(key, i)
                got = cache.get(key)
                assert got is None or isinstance(got, int)
                cache.get((tid ^ 1, i % 64))
                if i % 97 == 0:
                    cache.invalidate()
                len(cache), cache.stats()
                i += 1
        except BaseException as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.5)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors, errors
    s = cache.stats()
    assert s["size"] <= 32
    assert s["hits"] + s["misses"] > 0


def test_hgemms_concurrent_plan_and_refit():
    """Concurrent plan() (cache get/put) against observe() (invalidate)
    must not corrupt the cache or serve a stale plan type."""
    hg = HGemms(paper_mach1(), dynamic=True)
    errors = []
    stop = threading.Event()

    def planner():
        try:
            while not stop.is_set():
                p = hg.plan(2048, 1024, 512)
                assert p.adapted.total_rows() == 2048
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    def refitter():
        try:
            i = 0
            while not stop.is_set():
                hg.dyn.observe(1, 1e9 * (1 + i % 3),
                               hg.devices[1].compute(1e9) * (1 + 0.1 * (i % 5)))
                i += 1
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=planner) for _ in range(3)] + \
        [threading.Thread(target=refitter)]
    for t in threads:
        t.start()
    import time
    time.sleep(0.7)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert not errors, errors


# ------------------------------------------------- solve_analytic guard -----

def test_solve_analytic_zero_slope_no_crash():
    """Regression: LinearTimeModel(a=0, b=...) raised ZeroDivisionError."""
    devs = [DeviceProfile("const", "cpu", LinearTimeModel(a=0.0, b=5e-3),
                          NO_COPY),
            DeviceProfile("lin", "gpu", LinearTimeModel(a=1e-12, b=1e-4),
                          NO_COPY)]
    r = solve_analytic(devs, 1e9, n=100, k=100)
    assert sum(r.ops) == pytest.approx(1e9, rel=1e-9)
    assert math.isfinite(r.makespan)


def test_solve_analytic_zero_slope_device_wins_when_cheaper():
    # constant 1 ms beats the linear device needing 10 ms: hand it all over
    devs = [DeviceProfile("const", "cpu", LinearTimeModel(a=0.0, b=1e-3),
                          NO_COPY),
            DeviceProfile("lin", "gpu", LinearTimeModel(a=1e-11, b=0.0),
                          NO_COPY)]
    r = solve_analytic(devs, 1e9, n=100, k=100)
    assert r.ops[0] == pytest.approx(1e9)
    assert r.makespan == pytest.approx(1e-3)


def test_solve_analytic_all_zero_slope():
    devs = [DeviceProfile("c1", "cpu", LinearTimeModel(a=0.0, b=2e-3),
                          NO_COPY),
            DeviceProfile("c2", "cpu", LinearTimeModel(a=0.0, b=1e-3),
                          NO_COPY)]
    r = solve_analytic(devs, 1e9, n=100, k=100)
    assert r.ops[1] == pytest.approx(1e9)  # cheaper constant device
    assert r.makespan == pytest.approx(1e-3)
