"""Predict-phase satellites: profile persistence round-trip and the
``fit_linear`` degenerate-branch slope clamp."""
import math

import pytest

from repro.core import (CopyModel, DeviceProfile, LinearTimeModel, NO_COPY,
                        RooflineTimeModel, fit_linear, load_profiles,
                        save_profiles, tpu_group)
from repro.core.optimize import solve_analytic, solve_bisection


# ------------------------------------------------ save/load round-trip ------

def _testbed():
    return [
        # linear model + NO_COPY (host CPU computing in place)
        DeviceProfile("cpu", "cpu", LinearTimeModel(a=7.1e-12, b=1e-4),
                      NO_COPY, align_m=1, cache_bytes=15e6),
        # linear model + finite-bandwidth copy with latency + pipelining
        DeviceProfile("gpu", "gpu", LinearTimeModel(a=1.6e-13, b=2e-4),
                      CopyModel(15.75e9, dtype_size=2, latency_s=3e-5),
                      align_m=8, align_k=8, cache_bytes=6e6,
                      pipeline_chunks=4),
        # roofline model (TPU group)
        tpu_group("tpu", 8, derate=0.9),
    ]


def test_profiles_round_trip(tmp_path):
    path = str(tmp_path / "profiles.json")
    devices = _testbed()
    save_profiles(path, devices)
    loaded = load_profiles(path)
    assert len(loaded) == len(devices)
    for orig, back in zip(devices, loaded):
        assert back == orig   # frozen dataclasses compare by value


def test_profiles_round_trip_preserves_model_types_and_times(tmp_path):
    path = str(tmp_path / "profiles.json")
    save_profiles(path, _testbed())
    cpu, gpu, tpu = load_profiles(path)
    assert isinstance(cpu.compute, LinearTimeModel)
    assert isinstance(gpu.compute, LinearTimeModel)
    assert isinstance(tpu.compute, RooflineTimeModel)
    # NO_COPY survives as the infinite-bandwidth sentinel
    assert math.isinf(cpu.copy.bandwidth_bytes_per_s)
    assert cpu.copy(1e9, 1000, 1000) == 0.0
    # times (the scheduling contract) are identical
    for d0, d1 in zip(_testbed(), (cpu, gpu, tpu)):
        for c in (1e6, 1e9, 5e10):
            assert d1.compute(c) == pytest.approx(d0.compute(c), rel=0.0)
            assert d1.copy(c, 2048, 2048) == pytest.approx(
                d0.copy(c, 2048, 2048), rel=0.0)
        assert d1.pipeline_chunks == d0.pipeline_chunks


def test_loaded_profiles_plan_identically(tmp_path):
    """A plan solved on loaded profiles equals one solved on the originals
    (the round-trip preserves everything the solver reads)."""
    path = str(tmp_path / "profiles.json")
    devices = _testbed()
    save_profiles(path, devices)
    loaded = load_profiles(path)
    r0 = solve_bisection(devices, 1e12, n=4096, k=4096, bus="serialized")
    r1 = solve_bisection(loaded, 1e12, n=4096, k=4096, bus="serialized")
    assert r1.ops == pytest.approx(r0.ops, rel=1e-12)
    assert r1.makespan == pytest.approx(r0.makespan, rel=1e-12)


# -------------------------------------------- fit_linear degenerate ---------

def test_fit_linear_single_size_clamps_slope():
    """Regression (satellite): the single-size branch returned a=0 when
    mx == 0, a zero-slope 'free compute' model every solver must
    special-case; it must clamp to the same 1e-18 floor as the main path."""
    m = fit_linear([0.0], [0.0])
    assert m.a >= 1e-18
    m = fit_linear([0.0, 0.0], [0.0, 0.0])
    assert m.a >= 1e-18


def test_fit_linear_single_size_keeps_throughput():
    # a genuine single-size sample still yields the throughput-only model
    m = fit_linear([2e9, 2e9], [4e-3, 4e-3])
    assert m.a == pytest.approx(2e-12)
    assert m.b == 0.0


def test_fit_linear_degenerate_model_safe_for_solvers():
    """The clamped degenerate model goes straight through both solvers
    without special-casing."""
    devs = [DeviceProfile("deg", "cpu", fit_linear([0.0], [0.0]), NO_COPY),
            DeviceProfile("lin", "gpu", LinearTimeModel(a=1e-12, b=1e-4),
                          NO_COPY)]
    r = solve_analytic(devs, 1e9, n=100, k=100)
    assert sum(r.ops) == pytest.approx(1e9, rel=1e-9)
    r2 = solve_bisection(devs, 1e9, n=100, k=100, bus="independent")
    assert sum(r2.ops) == pytest.approx(1e9, rel=1e-6)
    assert math.isfinite(r2.makespan)
