"""Generic adapt-phase primitives (core/adapt.py): degenerate-input
coverage for ``pack_largest_first`` and ``round_shares_to_grain`` — the
shared machinery under the serving-dispatch and train-step domains."""
import pytest

from repro.core.adapt import pack_largest_first, round_shares_to_grain


# ------------------------------------------------- pack_largest_first -------

def _flatten(buckets):
    return sorted(i for b in buckets for i in b)


def test_pack_zero_weights_places_every_item_exactly_once():
    buckets = pack_largest_first([0.0] * 5, [3.0, 1.0])
    assert _flatten(buckets) == list(range(5))
    # zero-weight items never reduce remaining budget, so they all land in
    # the largest-budget bucket — any packing ties, this one is stable
    assert buckets[0] == [0, 1, 2, 3, 4] and buckets[1] == []


def test_pack_equal_weights_balances_equal_budgets():
    buckets = pack_largest_first([2.0] * 6, [6.0, 6.0, 6.0])
    assert _flatten(buckets) == list(range(6))
    assert sorted(len(b) for b in buckets) == [2, 2, 2]


def test_pack_equal_weights_tracks_unequal_budgets():
    buckets = pack_largest_first([1.0] * 8, [6.0, 2.0])
    assert _flatten(buckets) == list(range(8))
    assert len(buckets[0]) == 6 and len(buckets[1]) == 2


def test_pack_empty_items_and_single_bucket():
    assert pack_largest_first([], [4.0, 4.0]) == [[], []]
    assert pack_largest_first([3.0, 1.0, 2.0], [1.0]) == [[0, 2, 1]]


def test_pack_orders_heaviest_first_within_buckets():
    buckets = pack_largest_first([5.0, 1.0, 3.0], [100.0])
    assert buckets == [[0, 2, 1]]


# ---------------------------------------------- round_shares_to_grain -------

def test_round_grain_exceeding_total_still_conserves():
    # a single bucket whose grain is larger than the whole total: the
    # remainder hand-out must break the grain rather than lose rows
    assert round_shares_to_grain([7.0], [16], 7) == [7]
    # two buckets, both grains above the total — all rows go to the
    # largest-shortfall bucket as one sub-grain packet
    assert sum(round_shares_to_grain([10.2, 5.8], [32, 16], 16)) == 16


def test_round_shares_rounding_to_zero_get_remainder_packets():
    # every share floors to zero; largest fractional shortfall wins
    out = round_shares_to_grain([0.4, 0.6], [1, 1], 1)
    assert out == [0, 1]
    out = round_shares_to_grain([0.2, 0.3, 0.5], [4, 4, 4], 4)
    assert sum(out) == 4 and out[2] == 4


def test_round_shares_trims_over_assignment_from_largest():
    # raw shares sum above the total: floors over-assign and the largest
    # bucket absorbs the trim
    out = round_shares_to_grain([16.0, 8.0], [8, 8], 16)
    assert sum(out) == 16
    assert out == [8, 8]


def test_round_shares_zero_total():
    assert round_shares_to_grain([0.0, 0.0], [8, 8], 0) == [0, 0]


def test_round_shares_respects_grain_when_possible():
    out = round_shares_to_grain([33.0, 31.0], [16, 16], 64)
    assert sum(out) == 64
    assert all(x % 16 == 0 for x in out)
