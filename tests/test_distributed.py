"""Hetero-DP scheduler, gradient compression, and sharding-rule tests."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.hetero import (BatchSplit, HeteroBatchScheduler,
                                      PodProfile)


# -------------------------------------------------------------- hetero DP --

PODS = [
    PodProfile("pod0", chips=256, peak_flops=197e12, grain=16),
    PodProfile("pod1", chips=256, peak_flops=197e12, grain=16),
]


def test_equal_pods_equal_split():
    s = HeteroBatchScheduler(PODS, flops_per_token=6 * 12e9, seq_len=4096)
    split = s.plan(256)
    assert sum(split.sizes) == 256
    assert split.sizes[0] == split.sizes[1] == 128
    assert all(x % 16 == 0 for x in split.sizes)


def test_derated_pod_gets_less():
    pods = [PODS[0], PodProfile("slow", 256, 197e12, derate=0.5, grain=16)]
    s = HeteroBatchScheduler(pods, flops_per_token=6 * 12e9, seq_len=4096)
    split = s.plan(256)
    assert sum(split.sizes) == 256
    assert split.sizes[0] > split.sizes[1]
    assert split.sizes[0] / max(split.sizes[1], 1) == pytest.approx(2.0,
                                                                    rel=0.35)


def test_dynamic_straggler_rebalance():
    s = HeteroBatchScheduler(PODS, flops_per_token=6 * 12e9, seq_len=4096,
                             dynamic=True)
    split0 = s.plan(256)
    # pod1 starts straggling 3x: feed observations of measured step times
    for step in range(4):
        t0 = s.devices[0].compute(split0.sizes[0] * 4096)
        s.observe(0, split0.sizes[0], t0)
        s.observe(1, split0.sizes[1], 3.0 * t0 * (1 + 0.01 * step))
    split1 = s.plan(256)
    assert split1.sizes[0] > 2 * split1.sizes[1]
    assert sum(split1.sizes) == 256
    # imbalance estimate should be small after rebalancing
    assert s.imbalance(split1) < 0.35


def test_split_grain_and_conservation_property():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n_pods = rng.integers(1, 5)
        pods = [PodProfile(f"p{i}", 256, 197e12,
                           derate=float(rng.uniform(0.3, 1.0)), grain=8)
                for i in range(n_pods)]
        s = HeteroBatchScheduler(pods, flops_per_token=1e9, seq_len=1024,
                                 dynamic=False)
        gb = int(rng.integers(1, 40)) * 8
        split = s.plan(gb)
        assert sum(split.sizes) == gb
        assert all(x >= 0 for x in split.sizes)


# ------------------------------------------------- compressed collectives --

def test_int8_quantization_error_bounded():
    from repro.distributed.collectives import dequantize_int8, quantize_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 0.01
    q, scale = quantize_int8(x, jax.random.PRNGKey(1))
    x2 = dequantize_int8(q, scale, jnp.float32)
    # max error is one quantization step
    assert float(jnp.max(jnp.abs(x2 - x))) <= float(scale) * 1.01


def test_int8_stochastic_rounding_unbiased():
    from repro.distributed.collectives import dequantize_int8, quantize_int8
    x = jnp.full((4096,), 0.3e-2)
    errs = []
    for i in range(20):
        q, s = quantize_int8(x, jax.random.PRNGKey(i))
        errs.append(float(jnp.mean(dequantize_int8(q, s, jnp.float32) - x)))
    assert abs(np.mean(errs)) < 5e-6  # zero-mean across keys


def test_compressed_psum_subprocess():
    """shard_map psum with int8 compression on 4 forced host devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import compressed_psum_mean
mesh = jax.make_mesh((4,), ("pod",), devices=jax.devices())
x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) / 100.0

def body(xl, key):
    return compressed_psum_mean(xl[0], "pod", key, mode="int8")[None]

if hasattr(jax, "shard_map"):          # jax >= 0.6 moved it to the top level
    shard_map, kw = jax.shard_map, {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map
    kw = {"check_rep": False}
out = jax.jit(shard_map(body, mesh=mesh,
    in_specs=(P("pod", None), P()), out_specs=P("pod", None),
    **kw))(x, jax.random.PRNGKey(0))
expected = x.mean(axis=0)
err = float(jnp.max(jnp.abs(out - expected[None])))
assert err < 2e-3, err
print("OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                        "PYTHONPATH": "src"},
                       cwd=__import__("pathlib").Path(__file__).parent.parent)
    assert "OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------------------- shardings --

def test_sharding_rules_subprocess():
    """Param spec rules on a (2,2,2) mesh: TP/FSDP axes land where expected."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.configs import get_tiny_config
from repro.launch.specs import param_specs
from repro.distributed.sharding import param_shardings
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     devices=jax.devices())
cfg = get_tiny_config("stablelm-12b")
specs = param_specs(cfg)
sh = param_shardings(specs, mesh)
assert sh["embed"].spec == P("model", "data"), sh["embed"].spec
assert sh["layers"]["attn"]["wq"].spec == P(None, "data", "model", None)
# tiny cfg: kv=2 divides the size-2 model axis, so KH itself shards
assert sh["layers"]["attn"]["wk"].spec == P(None, "data", "model", None)
assert sh["layers"]["mlp"]["wi"].spec == P(None, "data", "model")
assert all(a is None for a in sh["layers"]["ln1"]["scale"].spec)
cfg2 = get_tiny_config("dbrx-132b")
sh2 = param_shardings(param_specs(cfg2), mesh)
assert sh2["layers"]["moe"]["w_in"].spec == P(None, "model", "data", None)
print("OK")
"""
    import os
    import pathlib
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=pathlib.Path(__file__).parent.parent)
    assert "OK" in r.stdout, r.stderr[-2000:]
