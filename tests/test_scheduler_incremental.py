"""Incremental scheduling engine — exactness properties (DESIGN.md §12).

The checkpoint/extend contract: a ``GraphSimState`` advanced in arbitrary
chunks, under carried clocks and external ``ext`` finish times, must
produce finish times *byte-identical* to the canonical from-scratch
``graph_finish_times`` — and the EFT placement built on candidate peeks
(scalar and vectorized) must reproduce the pre-PR full-prefix-resim
placement exactly.
"""
import math

import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (BusTopology, ClockState, CopyModel, DeviceProfile,
                        GraphSimContext, GraphSimState, LinearTimeModel,
                        NO_COPY, TaskSpec, graph_finish_times,
                        solve_list_schedule)
from repro.core.optimize import _DeviceArrays, _EPS, _peek_batch


def _devs():
    return [
        DeviceProfile("cpu", "cpu", LinearTimeModel(a=1 / 5e12, b=1e-4),
                      NO_COPY),
        DeviceProfile("gpu0", "gpu", LinearTimeModel(a=1 / 60e12, b=5e-5),
                      CopyModel(16e9, dtype_size=4)),
        DeviceProfile("gpu1", "gpu", LinearTimeModel(a=1 / 25e12, b=8e-5),
                      CopyModel(8e9, dtype_size=4)),
    ]


_bytes = st.one_of(st.just(0.0), st.floats(1e3, 1e9))


@st.composite
def _dag(draw):
    """A random DAG in natural topological order, with zero byte/op counts
    mixed in so the no-copy / no-output fast paths are exercised."""
    n = draw(st.integers(2, 8))
    edges = tuple((u, v) for u in range(n) for v in range(u + 1, n)
                  if draw(st.booleans()))
    tasks = [TaskSpec(name=f"t{i}", ops=draw(st.floats(0.0, 1e12)),
                      in_bytes=draw(_bytes), out_bytes=draw(_bytes))
             for i in range(n)]
    return tasks, edges


@settings(max_examples=40, deadline=None)
@given(case=_dag(), data=st.data())
def test_incremental_equals_from_scratch(case, data):
    """Chunked GraphSimState.advance == graph_finish_times, exactly —
    under random assignments (including unplaced), carried clocks, and
    random ``ext`` maps (including infinite avail)."""
    tasks, edges = case
    n = len(tasks)
    devs = _devs()
    topo = BusTopology.from_spec("serialized", devs)
    order = list(range(n))
    assign = [data.draw(st.integers(-1, 2)) for _ in range(n)]
    clocks = ClockState(
        devices={d.name: data.draw(st.floats(0.0, 0.01)) for d in devs},
        floor=data.draw(st.floats(0.0, 0.01)))
    ext = {}
    for i in range(n):
        if data.draw(st.booleans()):
            ce = data.draw(st.floats(0.0, 0.02))
            av = (math.inf if data.draw(st.booleans())
                  else ce + data.draw(st.floats(0.0, 0.01)))
            ext[i] = (ce, av)
    ctx = GraphSimContext(devs, tasks, edges, topo, order,
                          clocks=clocks, ext=ext)
    state = GraphSimState(ctx, list(assign))
    for cut in sorted(data.draw(st.lists(st.integers(0, n), max_size=3))):
        state.advance(cut)
    state.advance(n)
    ref = graph_finish_times(devs, tasks, edges, assign, topology=topo,
                             order=order, clocks=clocks, ext=ext)
    assert state.finish == ref


@settings(max_examples=30, deadline=None)
@given(case=_dag(), data=st.data())
def test_peek_prices_match_committed_engine(case, data):
    """peek_finish (scalar) and _peek_batch (vectorized) price every
    candidate byte-identically to what committing it would produce."""
    tasks, edges = case
    n = len(tasks)
    devs = _devs()
    topo = BusTopology.from_spec("serialized", devs)
    order = list(range(n))
    ctx = GraphSimContext(devs, tasks, edges, topo, order)
    sim = GraphSimState(ctx, [-1] * n, placed=[])
    da = _DeviceArrays(ctx)
    for pos, i in enumerate(order):
        peeks = [sim.peek_finish(i, j) for j in range(len(devs))]
        assert [float(v) for v in _peek_batch(sim, da, i)] == peeks
        j = data.draw(st.integers(0, len(devs) - 1))
        sim.assign[i] = j
        sim.placed[i] = 1
        sim.advance(pos + 1)
        assert sim.finish[i] == peeks[j]


@settings(max_examples=15, deadline=None)
@given(case=_dag(), data=st.data())
def test_solver_matches_scratch_eft(case, data):
    """solve_list_schedule's incremental EFT placement (with random
    pinned subsets) equals the pre-PR loop that re-simulated the whole
    placed prefix for every (task, device) candidate."""
    tasks, edges = case
    n = len(tasks)
    devs = _devs()
    topo = BusTopology.from_spec("serialized", devs)
    pinned = {i: data.draw(st.integers(0, len(devs) - 1))
              for i in range(n) if data.draw(st.booleans())}
    res = solve_list_schedule(devs, tasks, edges, bus=topo, refine=False,
                              pinned=pinned)
    order = list(res.order)
    assign = [-1] * n
    for i, j in pinned.items():
        assign[i] = j
    for pos, i in enumerate(order):
        if i in pinned:
            continue
        best_j, best_t = 0, math.inf
        for j in range(len(devs)):
            assign[i] = j
            t = graph_finish_times(devs, tasks, edges, assign,
                                   topology=topo, order=order[:pos + 1])[i]
            if t < best_t - _EPS:
                best_j, best_t = j, t
        assign[i] = best_j
    assert list(res.assign) == assign
    assert res.task_finish == graph_finish_times(
        devs, tasks, edges, assign, topology=topo, order=order)
