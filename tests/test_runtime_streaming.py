"""Persistent streaming co-execution runtime: carried clocks, the
plan→execute→observe→re-plan loop, continuous serving dispatch, and the
cross-plan invariants (DESIGN.md §9)."""
import threading
import time

import numpy as np
import pytest

from repro.core import (BusTopology, ClockState, CoExecutionRuntime,
                        CopyModel, DeviceProfile, GemmDomain, GemmWorkload,
                        LinearTimeModel, NO_COPY, ObservationPump,
                        build_timeline, carry_clocks, paper_mach1,
                        simulate_timeline, throttled, truth_from_profiles,
                        verify_stream_invariants)
from repro.core.schedule import DynamicScheduler


def _mk(name, tflops, bw=None, b=1e-4):
    ops_per_s = tflops * 1e12 / 2
    copy = NO_COPY if bw is None else CopyModel(bw, dtype_size=4)
    return DeviceProfile(name, "gpu" if bw else "cpu",
                         LinearTimeModel(a=1 / ops_per_s, b=b), copy)


THROTTLE_AT = 6
N_JOBS = 20
SHAPE = GemmWorkload(4096, 4096, 4096)


def _truth(factor=3.0, device="2080ti-tensor", at=THROTTLE_AT):
    return truth_from_profiles(
        paper_mach1(),
        lambda uid, name: factor if uid >= at and name == device else 1.0)


# ------------------------------------------------------- carried clocks -----

def test_carried_clocks_default_is_t0():
    devs = paper_mach1()
    ops = [1e9, 2e10, 5e10]
    a = build_timeline(devs, ops, 4096, 4096)
    b = build_timeline(devs, ops, 4096, 4096, clocks=ClockState())
    assert [(e.device, e.kind, e.start, e.end) for e in a.events] == \
        [(e.device, e.kind, e.start, e.end) for e in b.events]


def test_carried_clocks_chain_two_plans():
    """Plan 2 built from plan 1's carried clocks: its first transfer on each
    link starts exactly where plan 1 left that link, and each device's first
    stage starts no earlier than its own plan-1 finish — but CAN start well
    before plan 1's global makespan (the overlap)."""
    devs = paper_mach1()
    ops = [1e9, 2e10, 5e10]
    t1 = build_timeline(devs, ops, 4096, 4096)
    clocks = carry_clocks(t1)
    t2 = build_timeline(devs, ops, 4096, 4096, clocks=clocks)
    # per-link serialization holds across the boundary
    evs = sorted((e for e in t1.events + t2.events if e.kind != "compute"),
                 key=lambda e: (e.start, e.end))
    for a, b in zip(evs, evs[1:]):
        assert b.start >= a.end - 1e-12, (a, b)
    # each device's plan-2 stages start only after its own plan-1 finish
    for d in devs:
        if not t1.device_events(d.name):
            continue
        fin1 = t1.device_finish(d.name)
        first2 = min(e.start for e in t2.device_events(d.name))
        assert first2 >= fin1 - 1e-12
    # the overlap: at least one device starts plan 2 before plan 1's global
    # makespan (this is what a barrier would forbid)
    starts2 = [min(e.start for e in t2.device_events(d.name))
               for d in devs if t2.device_events(d.name)]
    assert min(starts2) < t1.makespan - 1e-9


def test_carried_clocks_barrier_floor():
    devs = paper_mach1()
    ops = [1e9, 2e10, 5e10]
    t1 = build_timeline(devs, ops, 4096, 4096)
    t2 = build_timeline(devs, ops, 4096, 4096,
                        clocks=ClockState(floor=t1.makespan))
    assert min(e.start for e in t2.events) >= t1.makespan - 1e-12


def test_carried_chain_beats_barrier_chain():
    """Back-to-back plans overlap where they stress *different* devices: a
    CPU-critical plan followed by an XPU-only plan — with carried clocks
    the XPU's copies and compute run entirely under the CPU's tail, while a
    barrier serializes the two plans.  (A stream of identical plans ties:
    the slowest device chains on itself in both modes.)"""
    devs = paper_mach1()
    cpu_plan = [2e9, 0.0, 0.0]     # ~14 ms of host compute, bus idle
    xpu_plan = [0.0, 0.0, 3e10]    # ~6 ms of copies + MXU compute
    carried = ClockState()
    barrier = ClockState()
    total_c = total_b = 0.0
    for ops in (cpu_plan, xpu_plan):
        tc = build_timeline(devs, ops, 4096, 4096, clocks=carried)
        carried = carry_clocks(tc)
        total_c = max(total_c, tc.makespan)
        tb = build_timeline(devs, ops, 4096, 4096, clocks=barrier)
        barrier = ClockState(floor=tb.makespan)
        total_b = max(total_b, tb.makespan)
    assert total_c < total_b - 1e-9
    # fully hidden: the XPU plan ends inside the CPU plan's compute tail
    assert total_c == pytest.approx(devs[0].compute(cpu_plan[0]))


def test_spec_rebase_reproduces_schedule_timeline():
    dom = GemmDomain(paper_mach1(), bus="serialized")
    from repro.core import POAS
    plan = POAS(dom).plan(SHAPE)
    spec = plan.schedule.spec
    assert spec is not None
    rb = spec.rebase()
    assert [(e.device, e.kind, e.start, e.end) for e in rb.events] == \
        [(e.device, e.kind, e.start, e.end)
         for e in plan.schedule.timeline.events]


def test_spec_rebase_with_truth_keeps_planned_order():
    """Replaying a plan under ground-truth models must keep the planned
    ticket order even when the substituted models would re-rank devices."""
    dom = GemmDomain(paper_mach1(), bus="serialized")
    from repro.core import POAS
    plan = POAS(dom).plan(SHAPE)
    spec = plan.schedule.spec
    truth = [throttled(d, 50.0) if d.name == "2080ti-tensor" else d
             for d in spec.devices]
    rb = spec.rebase(devices=truth)
    assert rb.link_ticket_order() == plan.schedule.timeline.link_ticket_order()


# --------------------------------------------------- observation pump -------

def test_pump_feeds_compute_events():
    devs = [_mk("a", 1.0), _mk("b", 2.0)]
    dyn = DynamicScheduler(devs, bus="independent")
    pump = ObservationPump(dyn, ["a", "b"])
    tl = simulate_timeline(devs, [1e9, 2e9], 1, 1, topology="independent")
    fed = pump.feed(tl, {"a": 1e9, "b": 2e9})
    assert fed == 2
    assert pump.observations == 2
    # devices with no ops are skipped
    assert pump.feed(tl, {"a": 0.0}) == 0


def test_pump_time_scale_converts_to_model_seconds():
    devs = [_mk("a", 1.0)]
    dyn = DynamicScheduler(devs, bus="independent", min_obs=1)
    pump = ObservationPump(dyn, ["a"], time_scale=0.1)
    true_s = devs[0].compute(1e9)
    pump.observe("a", 1e9, true_s * 0.1)   # wall time at 10% scale
    # the rescale path should see ratio 1.0 -> model unchanged
    assert dyn.devices[0].compute(1e9) == pytest.approx(true_s, rel=1e-9)


# ------------------------------------------------- the loop (virtual) -------

def _run(feedback, carry, truth=None, n_jobs=N_JOBS, max_inflight=2):
    dom = GemmDomain(paper_mach1(), bus="serialized", dynamic=feedback)
    rt = CoExecutionRuntime(dom, executor="virtual",
                            truth=truth or _truth(),
                            feedback=feedback, carry_clocks=carry,
                            max_inflight=max_inflight)
    try:
        jobs = rt.run_stream([SHAPE] * n_jobs)
        return rt, dom, jobs
    finally:
        rt.shutdown()


def test_feedback_loop_beats_static_plan():
    """Acceptance: >= 20 streamed GEMMs on paper_mach1, one device throttled
    mid-stream — the feedback loop's total makespan beats the static plan's."""
    rt_fb, _, jobs_fb = _run(feedback=True, carry=True)
    rt_st, _, jobs_st = _run(feedback=False, carry=True)
    assert len(jobs_fb) == N_JOBS
    assert rt_fb.total_makespan() < rt_st.total_makespan() - 1e-9
    assert verify_stream_invariants(jobs_fb) == []
    assert verify_stream_invariants(jobs_st) == []


def test_throttled_device_sheds_load_within_bounded_iterations():
    """After the 2x throttle at job 6, the runtime must re-fit and shed the
    throttled device's share within 4 jobs — with PlanCache epoch bumps
    (invalidations) asserted along the way."""
    rt, dom, jobs = _run(feedback=True, carry=True)
    xpu = 2   # 2080ti-tensor index in paper_mach1
    share0 = jobs[THROTTLE_AT - 1].plan.optimize.shares()[xpu]
    shed = [j.uid for j in jobs[THROTTLE_AT:]
            if j.plan.optimize.shares()[xpu] < 0.75 * share0]
    assert shed, "throttled device never shed load"
    assert min(shed) <= THROTTLE_AT + 4, \
        f"shed only at job {min(shed)} (throttle at {THROTTLE_AT})"
    # feedback loop bookkeeping: re-fits bumped the epoch and invalidated
    # the plan cache; later plans were solved under a newer epoch
    assert dom.dyn.epoch > 0
    assert dom.dyn.window_resets >= 1      # change-point reset fired
    assert rt.plan_cache.invalidations >= 1
    assert jobs[-1].epoch_at_plan > jobs[0].epoch_at_plan


def test_carry_clocks_improves_stream_makespan():
    rt_on, _, jobs_on = _run(feedback=False, carry=True)
    rt_off, _, jobs_off = _run(feedback=False, carry=False)
    assert rt_on.total_makespan() <= rt_off.total_makespan() + 1e-12
    # measured timelines in both modes satisfy the invariants
    assert verify_stream_invariants(jobs_on) == []
    assert verify_stream_invariants(jobs_off) == []


def test_virtual_stream_invariants_across_plan_boundaries():
    rt, _, jobs = _run(feedback=True, carry=True)
    assert verify_stream_invariants(jobs) == []
    # the whole stream shares one time axis and strictly serializes pcie
    stream = rt.stream_timeline()
    pcie = stream.link_events("pcie")
    assert len(pcie) > N_JOBS          # several transfers per job
    for a, b in zip(pcie, pcie[1:]):
        assert b.start >= a.end - 1e-9


# ------------------------------------------------- the loop (threads) -------

def test_threaded_runtime_streams_jobs_with_invariants():
    """The real StreamCore: persistent per-device workers + per-link ticket
    buses surviving across plans.  Measured (wall-clock) timelines must pass
    the same invariants, across plan boundaries."""
    dom = GemmDomain(paper_mach1(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="threads", truth=_truth(at=3),
                            feedback=True, carry_clocks=True,
                            max_inflight=2) as rt:
        jobs = rt.run_stream([SHAPE] * 6)
        assert all(j.error is None for j in jobs)
        assert verify_stream_invariants(jobs) == []
        # the pump really fed the scheduler from measured timelines
        assert rt.pump.observations > 0
        assert dom.dyn.epoch > 0


def test_threaded_refit_lands_while_plan_executes():
    """Thread-safety: observe() re-fits land from completion threads while
    the planner thread is mid-plan.  Hammer both paths; nothing may crash,
    and every job must complete."""
    dom = GemmDomain(paper_mach1(), bus="serialized", dynamic=True)
    errors = []
    stop = threading.Event()

    def hammer():
        i = 0
        try:
            while not stop.is_set():
                dom.dyn.observe(i % 3, 1e9 * (1 + i % 4), 1e-3 * (1 + i % 7))
                i += 1
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    t = threading.Thread(target=hammer, daemon=True)
    t.start()
    try:
        with CoExecutionRuntime(dom, executor="threads", truth=_truth(at=2),
                                feedback=True, carry_clocks=True) as rt:
            jobs = rt.run_stream([SHAPE] * 5)
    finally:
        stop.set()
        t.join(timeout=10)
    assert not errors, errors
    assert all(j.error is None and j.measured is not None for j in jobs)
    assert dom.dyn.epoch > 0


def test_failing_done_callback_does_not_kill_device_worker():
    """Regression: a raising JobHandle done-callback (the runtime's own
    _complete chains into pump/refit/user listeners) ran unguarded on the
    persistent device worker thread — killing it and hanging every later
    job on that device.  The error must land on the handle instead."""
    from repro.core import DeviceTask, StreamCore
    core = StreamCore()
    try:
        task = [DeviceTask("dev", None, lambda: None, None)]
        h1 = core.dispatch(task, {})
        h1.add_done_callback(lambda h: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            h1.wait(10)
        # the worker survived: a second job on the same device completes
        h2 = core.dispatch(task, {})
        h2.wait(10)
    finally:
        core.shutdown()


def test_observation_error_does_not_wedge_runtime():
    """A blowing-up refit listener must fail that job, not the runtime."""
    dom = GemmDomain(paper_mach1(), bus="serialized", dynamic=True)

    def boom():
        raise RuntimeError("listener exploded")

    dom.dyn.add_refit_listener(boom)
    with CoExecutionRuntime(dom, executor="threads", truth=_truth(at=0),
                            feedback=True) as rt:
        j1 = rt.submit(SHAPE)
        with pytest.raises(RuntimeError, match="listener exploded"):
            j1.wait(30)
        # the loop keeps going: in-flight slots were released
        j2 = rt.submit(SHAPE)
        j2._done.wait(30)
        assert j2.done


def test_threaded_runtime_propagates_task_errors():
    dom = GemmDomain(paper_mach1(), bus="serialized")

    def bad_factory(job, plan):
        def boom():
            raise RuntimeError("stage failed")
        spec = plan.schedule.spec
        return [  # claim only the fastest device; its compute explodes
            __import__("repro.core", fromlist=["DeviceTask"]).DeviceTask(
                device=spec.devices[2].name, copy_in=lambda: None,
                compute=boom, copy_out=lambda: None)]

    with CoExecutionRuntime(dom, executor="threads",
                            task_factory=bad_factory) as rt:
        job = rt.submit(SHAPE)
        with pytest.raises(RuntimeError, match="stage failed"):
            job.wait(30)
        # the runtime survives a failed job: the next one still runs
        ok = rt.submit(SHAPE)
        with pytest.raises(RuntimeError, match="stage failed"):
            ok.wait(30)


# --------------------------------------- serving: continuous batching -------

def _groups():
    return [DeviceProfile("fast", "tpu-group", LinearTimeModel(a=1e-6),
                          NO_COPY),
            DeviceProfile("slow", "tpu-group", LinearTimeModel(a=3e-6),
                          NO_COPY)]


def _reqs(n, base=0, tok=24):
    from repro.serving.engine import Request
    return [Request(uid=base + i, tokens=np.arange(tok), max_new_tokens=8)
            for i in range(n)]


def test_dispatcher_admit_while_batch_in_flight():
    from repro.serving.engine import PoasDispatcher
    disp = PoasDispatcher(_groups(), dynamic=True)
    disp.admit(*_reqs(10))
    b1 = disp.dispatch_pending()
    assert sum(len(b) for b in b1) == 10
    # requests arriving "while the batch is in flight"
    disp.admit(*_reqs(4, base=100))
    assert disp.pending == 4
    b2 = disp.dispatch_pending()
    assert sorted(r.uid for b in b2 for r in b) == [100, 101, 102, 103]
    assert disp.pending == 0
    assert disp.dispatch_pending() == [[], []]


def test_dispatcher_measured_times_refit_group_models():
    """Per-bucket measured times flow through the pump into group models:
    a 'fast' replica that measures 4x slower sheds requests on the next
    dispatch, and the PlanCache is invalidated (never serves the stale
    packing)."""
    from repro.serving.engine import PoasDispatcher
    disp = PoasDispatcher(_groups(), dynamic=True)
    disp.admit(*_reqs(30))
    b1 = disp.dispatch_pending()
    n_fast_1 = len(b1[0])
    cache_inv0 = disp.poas.cache.invalidations
    # the fast replica reports 4x its predicted bucket time, twice
    for _ in range(2):
        tok = sum(len(r.tokens) + r.max_new_tokens for r in b1[0])
        disp.complete(0, b1[0], 4.0 * disp.groups[0].compute(tok))
    assert disp.domain.dyn.epoch > 0
    assert disp.poas.cache.invalidations > cache_inv0
    disp.admit(*_reqs(30, base=200))
    b2 = disp.dispatch_pending()
    assert len(b2[0]) < n_fast_1      # shed load on the next dispatch


def test_predicted_makespan_includes_copy_time():
    """Satellite fix: predicted_makespan used to price g.compute(ops) only;
    it must now agree with simulate_timeline on the domain topology (copy
    time included for groups that have it)."""
    from repro.serving.engine import PoasDispatcher
    groups = [DeviceProfile("g0", "tpu-group", LinearTimeModel(a=1e-6),
                            CopyModel(1e6, dtype_size=4)),   # slow feed
              DeviceProfile("g1", "tpu-group", LinearTimeModel(a=1e-6),
                            NO_COPY)]
    disp = PoasDispatcher(groups)
    reqs = _reqs(8)
    buckets = disp.split(reqs)
    pred = disp.predicted_makespan(buckets)
    ops = [float(sum(len(r.tokens) + r.max_new_tokens for r in b))
           for b in buckets]
    tl = simulate_timeline(groups, ops, 1, 1,
                           topology=disp.domain.topology)
    assert pred == pytest.approx(tl.makespan, rel=1e-12)
    # and it is strictly above the compute-only number when a bucket copies
    compute_only = max(g.compute(c) for g, c in zip(groups, ops) if c > 0)
    if ops[0] > 0:
        assert pred > compute_only
    # regression: callers may pass fewer buckets than groups (the old
    # zip-based implementation tolerated it; the timeline path must too)
    assert disp.predicted_makespan(buckets[:1]) <= pred


def test_dispatcher_with_runtime_loop():
    """The serving-dispatch domain streams through the same runtime as
    GEMM: continuous batches, measured bucket times pumped back."""
    from repro.serving.engine import RequestBatch, ServingDispatchDomain
    dom = ServingDispatchDomain(_groups(), dynamic=True)
    truth = truth_from_profiles(
        _groups(), lambda uid, name: 3.0 if uid >= 3 and name == "fast"
        else 1.0)
    with CoExecutionRuntime(dom, executor="virtual", truth=truth,
                            feedback=True, max_inflight=1) as rt:
        jobs = rt.run_stream(
            [RequestBatch(requests=tuple(_reqs(16, base=32 * i)))
             for i in range(8)])
    assert verify_stream_invariants(jobs) == []
    # the throttled 'fast' group sheds tokens after the re-fit
    share_pre = jobs[2].plan.optimize.shares()[0]
    share_post = jobs[-1].plan.optimize.shares()[0]
    assert share_post < share_pre


# ----------------------------------------------- hetero: pump wiring --------

def test_hetero_feed_step_timeline_and_mapping():
    from repro.distributed.hetero import HeteroBatchScheduler, PodProfile
    pods = [PodProfile("pod0", 256, 197e12, grain=16),
            PodProfile("pod1", 256, 197e12, grain=16)]
    s = HeteroBatchScheduler(pods, flops_per_token=6 * 12e9, seq_len=4096,
                             dynamic=True)
    split = s.plan(256)
    # mapping form: pod1 3x slower
    t0 = s.devices[0].compute(split.sizes[0] * 4096)
    for _ in range(3):
        fed = s.feed_step(split, {"pod0": t0, "pod1": 3.0 * t0})
        assert fed == 2
    split2 = s.plan(256)
    assert split2.sizes[0] > split2.sizes[1]
    assert s.pump.observations >= 6
    # timeline form feeds the same pump
    tl = simulate_timeline(s.devices, [x * 4096 for x in split2.sizes],
                           1, 1, topology=s.domain.topology)
    assert s.feed_step(split2, tl) == sum(1 for x in split2.sizes if x > 0)
