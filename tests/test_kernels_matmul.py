"""Pallas matmul kernel vs pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul import matmul_pallas
from repro.kernels.ref import matmul_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),
    pytest.param(256, 512, 128, marks=pytest.mark.slow),
    pytest.param(64, 384, 256, marks=pytest.mark.slow),
    (100, 130, 50),      # ragged (padding path)
    (8, 128, 128),       # single sublane block
    pytest.param(512, 256, 512, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_allclose(m, k, n, dtype):
    ka, kb = jax.random.split(jax.random.PRNGKey(m * 7 + n))
    a = _rand(ka, (m, k), dtype)
    b = _rand(kb, (k, n), dtype)
    out = matmul_pallas(a, b, block_m=64, block_n=128, block_k=128,
                        interpret=True)
    ref = matmul_ref(a, b)
    # f32: accumulation-order noise grows with k (different block reduction
    # order than the XLA dot); bf16: input rounding dominates.
    rtol, atol = (1e-4, 1e-3) if dtype == jnp.float32 else (2e-2, 2e-1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=rtol, atol=atol)


def test_matmul_block_shape_independence():
    """Result must not depend on the chosen tiling."""
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = _rand(ka, (192, 256), jnp.float32)
    b = _rand(kb, (256, 192), jnp.float32)
    outs = [
        matmul_pallas(a, b, block_m=bm, block_n=bn, block_k=bk,
                      interpret=True)
        for bm, bn, bk in [(64, 128, 128), (192, 192, 256), (8, 128, 128)]
    ]
    for o in outs[1:]:
        # different k-block counts reduce in different orders
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-3)


def test_matmul_f32_accumulation_in_bf16():
    """bf16 inputs must accumulate in f32 (catches naive bf16 adds)."""
    k = 4096
    a = jnp.full((8, k), 0.01, jnp.bfloat16)
    b = jnp.full((k, 128), 0.01, jnp.bfloat16)
    out = matmul_pallas(a, b, interpret=True)
    expected = k * 0.01 * 0.01  # ~0.4096; bf16 accumulation would collapse
    rel = abs(float(out[0, 0]) - expected) / expected
    assert rel < 0.02, rel
