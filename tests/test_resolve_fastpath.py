"""Fast-path re-solve properties (DESIGN.md §14).

The §14 latency work changed the descent's quality contract from
bit-identical to *bounded*: pruned descent may visit fewer moves than the
full sweep, but its result must never be worse than the seed assignment
it started from, and a bound-aware ``advance`` that runs to completion
must remain byte-identical to the unbounded engine.  The deterministic
tests below always run; the hypothesis variants widen the same properties
over generated DAGs when hypothesis is installed.
"""
import math
import random

import pytest

from repro.core import (BusTopology, ClockState, CopyModel, DeviceProfile,
                        GraphSimContext, GraphSimState, LinearTimeModel,
                        NO_COPY, TaskSpec, solve_list_schedule)
from repro.core.optimize import (SolveContextCache, _descend_assign,
                                 _EPS)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _devs():
    return [
        DeviceProfile("cpu", "cpu", LinearTimeModel(a=1 / 5e12, b=1e-4),
                      NO_COPY),
        DeviceProfile("gpu0", "gpu", LinearTimeModel(a=1 / 60e12, b=5e-5),
                      CopyModel(16e9, dtype_size=4)),
        DeviceProfile("gpu1", "gpu", LinearTimeModel(a=1 / 25e12, b=8e-5),
                      CopyModel(8e9, dtype_size=4)),
    ]


def _random_case(rng, n_lo=3, n_hi=14):
    n = rng.randint(n_lo, n_hi)
    edges = tuple((u, v) for u in range(n) for v in range(u + 1, n)
                  if rng.random() < 0.35)
    tasks = [
        TaskSpec(name=f"t{i}",
                 ops=rng.choice([0.0, rng.uniform(0.0, 1e12)]),
                 in_bytes=rng.choice([0.0, rng.uniform(1e3, 1e9)]),
                 out_bytes=rng.choice([0.0, rng.uniform(1e3, 1e9)]))
        for i in range(n)]
    return tasks, edges


def _ctx(tasks, edges, devs, **kw):
    topo = BusTopology.from_spec("serialized", devs)
    return GraphSimContext(devs, tasks, edges, topo,
                           list(range(len(tasks))), **kw)


def test_pruned_descent_never_worse_than_seed():
    """Descent from any seed — pruned or not — returns a makespan <= the
    seed's own engine makespan (the §14 bounded-quality floor)."""
    rng = random.Random(0x5EED)
    devs = _devs()
    for _ in range(40):
        tasks, edges = _random_case(rng)
        n = len(tasks)
        ctx = _ctx(tasks, edges, devs)
        seed = [rng.randrange(len(devs)) for _ in range(n)]
        base = GraphSimState(ctx, list(seed))
        base.advance(n)
        seed_span = max(base.finish)
        for prune in (True, False):
            _, _, span, fin = _descend_assign(ctx, list(seed),
                                              max_evals=60, prune=prune)
            assert span <= seed_span + _EPS
            assert span == max(fin)


def test_bounded_advance_byte_identical_when_completed():
    """advance(bound=...) either aborts (returns False) or produces the
    exact finish vector of the unbounded engine — no drift from the
    early-exit bookkeeping."""
    rng = random.Random(0xB0D)
    devs = _devs()
    for _ in range(60):
        tasks, edges = _random_case(rng)
        n = len(tasks)
        ctx = _ctx(tasks, edges, devs)
        assign = [rng.randrange(len(devs)) for _ in range(n)]
        ref = GraphSimState(ctx, list(assign))
        assert ref.advance(n) is True
        span = max(ref.finish)
        for bound in (math.inf, span + 1.0, span,
                      span * rng.uniform(0.1, 1.0) - _EPS):
            stb = GraphSimState(ctx, list(assign))
            done = stb.advance(n, bound=bound)
            if done:
                assert stb.finish == ref.finish
                assert stb.compute_end == ref.compute_end
                assert stb.avail == ref.avail
            else:
                # aborted: some simulated finish exceeded the bound
                assert any(f > bound for f in stb.finish
                           if not math.isinf(f) or bound != math.inf)
        # a bound at the exact makespan must complete (abort is strict >)
        st_eq = GraphSimState(ctx, list(assign))
        assert st_eq.advance(n, bound=span) is True


def test_seed_budget_pool_never_overshoots():
    """Regression for the per-seed budget split: with a small cap and the
    3-way seed fan-out (EFT, seed_assign, rescue), total descent evals
    must stay within the shared pool, not len(seeds) * floor."""
    rng = random.Random(0xCAFE)
    devs = _devs()
    for _ in range(10):
        tasks, edges = _random_case(rng, n_lo=6, n_hi=14)
        n = len(tasks)
        eft = solve_list_schedule(devs, tasks, edges, refine=False)
        seed = [rng.randrange(len(devs)) for _ in range(n)]
        for cap in (3, 10, 60):
            res = solve_list_schedule(devs, tasks, edges, refine=True,
                                      seed_assign=seed, max_evals=cap)
            spent = res.iterations - eft.iterations
            # >= 1 eval per seed keeps the never-worse-than-seed floor
            # even when the cap is smaller than the seed count
            assert spent <= max(cap, 3)
            assert res.makespan <= eft.makespan + _EPS


def test_context_cache_equals_cold_solve():
    """A warm SolveContextCache re-solve — across changing clocks, pins,
    ext sets, and seeds — returns exactly what a cold solve returns; a
    device swap (model re-fit) misses and still matches."""
    rng = random.Random(0xCAC4E)
    devs = _devs()
    tasks, edges = _random_case(rng, n_lo=8, n_hi=14)
    n = len(tasks)
    cache = SolveContextCache()
    for trial in range(8):
        full = solve_list_schedule(devs, tasks, edges, refine=False)
        cut = rng.randint(1, n - 1)
        done = list(full.order)[:cut]
        pinned = {i: full.assign[i] for i in done}
        ext = {i: (full.task_finish[i], full.task_finish[i]) for i in done}
        clocks = ClockState(
            devices={d.name: rng.uniform(0.0, 0.005) for d in devs},
            floor=0.0)
        kw = dict(refine=True, pinned=pinned, ext=ext, clocks=clocks,
                  seed_assign=list(full.assign), max_evals=40)
        warm = solve_list_schedule(devs, tasks, edges, cache=cache, **kw)
        cold = solve_list_schedule(devs, tasks, edges, **kw)
        assert list(warm.assign) == list(cold.assign)
        assert warm.task_finish == cold.task_finish
        assert warm.makespan == cold.makespan
    # re-fit: new DeviceProfile objects -> key miss -> fresh tables
    refit = _devs()
    refit[1] = DeviceProfile("gpu0", "gpu",
                             LinearTimeModel(a=1 / 30e12, b=5e-5),
                             CopyModel(16e9, dtype_size=4))
    warm = solve_list_schedule(refit, tasks, edges, cache=cache,
                               refine=False)
    cold = solve_list_schedule(refit, tasks, edges, refine=False)
    assert list(warm.assign) == list(cold.assign)
    assert warm.task_finish == cold.task_finish


def test_price_lanes_matches_scalar_pricing():
    """The fused per-task pricing (one neighborhood walk for all lanes)
    is bit-identical to the scalar peek_finish/_stage_flip_info pair it
    replaced on the EFT hot path."""
    rng = random.Random(0xFA57)
    devs = _devs()
    for _ in range(40):
        tasks, edges = _random_case(rng)
        n = len(tasks)
        ext = {}
        for i in range(n):
            if rng.random() < 0.25:
                ce = rng.uniform(0.0, 0.02)
                av = (math.inf if rng.random() < 0.3
                      else ce + rng.uniform(0.0, 0.01))
                ext[i] = (ce, av)
        ctx = _ctx(tasks, edges, devs, ext=ext,
                   clocks=ClockState(devices={d.name: rng.uniform(0, 0.01)
                                              for d in devs}, floor=0.0))
        sim = GraphSimState(ctx, [-1] * n, placed=list(ext))
        nd = len(devs)
        for pos, i in enumerate(ctx.order):
            if i not in ext:
                ref_peeks = [sim.peek_finish(i, j) for j in range(nd)]
                ref_fp, ref_slack = [], []
                for j in range(nd):
                    fp, _, _, sl = sim._stage_flip_info(i, j)
                    ref_fp.append(fp)
                    ref_slack.append(sl)
                peeks, flips, slacks = sim.price_lanes(i, nd)
                assert peeks == ref_peeks
                assert flips == ref_fp
                assert slacks == ref_slack
                sim.assign[i] = rng.randrange(nd)
            sim.placed[i] = 1
            sim.advance(pos + 1)


if HAVE_HYPOTHESIS:
    _bytes = st.one_of(st.just(0.0), st.floats(1e3, 1e9))

    @st.composite
    def _dag(draw):
        n = draw(st.integers(2, 8))
        edges = tuple((u, v) for u in range(n) for v in range(u + 1, n)
                      if draw(st.booleans()))
        tasks = [TaskSpec(name=f"t{i}", ops=draw(st.floats(0.0, 1e12)),
                          in_bytes=draw(_bytes), out_bytes=draw(_bytes))
                 for i in range(n)]
        return tasks, edges

    @settings(max_examples=40, deadline=None)
    @given(case=_dag(), data=st.data())
    def test_hyp_pruned_descent_never_worse(case, data):
        tasks, edges = case
        n = len(tasks)
        devs = _devs()
        ctx = _ctx(tasks, edges, devs)
        seed = [data.draw(st.integers(0, len(devs) - 1))
                for _ in range(n)]
        base = GraphSimState(ctx, list(seed))
        base.advance(n)
        seed_span = max(base.finish)
        prune = data.draw(st.booleans())
        _, _, span, _ = _descend_assign(ctx, list(seed), max_evals=40,
                                        prune=prune)
        assert span <= seed_span + _EPS

    @settings(max_examples=40, deadline=None)
    @given(case=_dag(), data=st.data())
    def test_hyp_bounded_advance_identity(case, data):
        tasks, edges = case
        n = len(tasks)
        devs = _devs()
        ctx = _ctx(tasks, edges, devs)
        assign = [data.draw(st.integers(-1, len(devs) - 1))
                  for _ in range(n)]
        ref = GraphSimState(ctx, list(assign))
        ref.advance(n)
        bound = data.draw(st.one_of(
            st.just(math.inf), st.floats(0.0, 1.0)))
        stb = GraphSimState(ctx, list(assign))
        if stb.advance(n, bound=bound):
            assert stb.finish == ref.finish
