"""Serving engine + POAS dispatcher tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core.device_model import DeviceProfile, LinearTimeModel, NO_COPY
from repro.models import Model
from repro.serving.engine import PoasDispatcher, Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_tiny_config("stablelm-12b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServingEngine(model, params), cfg


def test_generate_batch(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, tokens=rng.integers(1, cfg.vocab_size, 6),
                    max_new_tokens=4) for i in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    for c in outs:
        assert c.tokens.shape == (4,)
        assert c.prefill_s >= 0 and c.decode_s >= 0


def test_generate_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(1)
    reqs = [Request(uid=0, tokens=rng.integers(1, cfg.vocab_size, 5),
                    max_new_tokens=6)]
    a = eng.generate(reqs)[0].tokens
    b = eng.generate(reqs)[0].tokens
    np.testing.assert_array_equal(a, b)


def _groups():
    return [
        DeviceProfile("fast", "tpu-group", LinearTimeModel(a=1e-6), NO_COPY),
        DeviceProfile("slow", "tpu-group", LinearTimeModel(a=3e-6), NO_COPY),
    ]


def test_dispatcher_balances_by_speed():
    disp = PoasDispatcher(_groups())
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, tokens=rng.integers(1, 100, 16),
                    max_new_tokens=16) for i in range(40)]
    buckets = disp.split(reqs)
    tok = [sum(len(r.tokens) + r.max_new_tokens for r in b) for b in buckets]
    assert sum(len(b) for b in buckets) == 40
    # 3x speed ratio -> fast gets ~3x the tokens
    assert tok[0] / max(tok[1], 1) == pytest.approx(3.0, rel=0.3)


def test_dispatcher_preserves_all_requests():
    disp = PoasDispatcher(_groups())
    reqs = [Request(uid=i, tokens=np.arange(1 + i % 7), max_new_tokens=2)
            for i in range(17)]
    buckets = disp.split(reqs)
    uids = sorted(r.uid for b in buckets for r in b)
    assert uids == list(range(17))


def test_dispatcher_empty():
    disp = PoasDispatcher(_groups())
    assert disp.split([]) == [[], []]
    assert disp.last_plan is None      # degenerate path never hits the solver


def test_dispatcher_single_group_degenerate():
    disp = PoasDispatcher([_groups()[0]])
    reqs = [Request(uid=i, tokens=np.arange(1 + i % 5), max_new_tokens=3)
            for i in range(9)]
    buckets = disp.split(reqs)
    assert len(buckets) == 1
    assert sorted(r.uid for r in buckets[0]) == list(range(9))
    res = disp.last_plan.optimize
    assert res.shares() == pytest.approx([1.0])


def test_dispatcher_bucket_tokens_track_optimize_shares():
    """Bucket token totals follow OptimizeResult.shares() to within the
    largest single request (greedy packing granularity)."""
    disp = PoasDispatcher(_groups())
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, tokens=rng.integers(1, 60, int(rng.integers(4, 40))),
                    max_new_tokens=int(rng.integers(1, 32)))
            for i in range(50)]
    buckets = disp.split(reqs)
    tok = [sum(len(r.tokens) + r.max_new_tokens for r in b) for b in buckets]
    total = sum(tok)
    biggest = max(len(r.tokens) + r.max_new_tokens for r in reqs)
    for t, share in zip(tok, disp.last_plan.optimize.shares()):
        assert abs(t - share * total) <= biggest


def test_dispatcher_is_a_registered_domain():
    from repro.core import list_domains
    from repro.serving.engine import ServingDispatchDomain
    assert "serving-dispatch" in list_domains()
    disp = PoasDispatcher(_groups())
    assert isinstance(disp.domain, ServingDispatchDomain)
    assert disp.poas.domain is disp.domain


def test_dispatcher_plan_cache_reuses_identical_geometry():
    disp = PoasDispatcher(_groups())
    reqs = [Request(uid=i, tokens=np.arange(8), max_new_tokens=4)
            for i in range(10)]
    b1 = disp.split(reqs)
    b2 = disp.split(reqs)
    assert disp.poas.cache.hits == 1
    assert [[r.uid for r in b] for b in b1] == [[r.uid for r in b] for b in b2]


def test_dispatcher_cache_does_not_pin_request_batches():
    """Cached plans must not retain the request objects (memory leak in a
    long-running dispatcher); only the index packing is memoized."""
    disp = PoasDispatcher(_groups())
    disp.split([Request(uid=0, tokens=np.arange(5), max_new_tokens=2)])
    (entry,) = disp.poas.cache._entries.values()
    assert entry.workload is None
    assert disp.last_plan.workload is not None   # caller's copy keeps it


def test_dispatcher_cached_plan_applies_to_fresh_requests():
    """A cache hit must bucket the NEW batch's requests, not replay the old
    request objects (same token geometry, different uids)."""
    disp = PoasDispatcher(_groups())
    mk = lambda base: [Request(uid=base + i, tokens=np.arange(8),
                               max_new_tokens=4) for i in range(10)]
    disp.split(mk(0))
    fresh = mk(100)
    buckets = disp.split(fresh)
    assert disp.poas.cache.hits == 1
    got = sorted(r.uid for b in buckets for r in b)
    assert got == list(range(100, 110))
