"""Serving engine + POAS dispatcher tests."""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.core.device_model import DeviceProfile, LinearTimeModel, NO_COPY
from repro.models import Model
from repro.serving.engine import PoasDispatcher, Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_tiny_config("stablelm-12b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServingEngine(model, params), cfg


def test_generate_batch(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, tokens=rng.integers(1, cfg.vocab_size, 6),
                    max_new_tokens=4) for i in range(3)]
    outs = eng.generate(reqs)
    assert len(outs) == 3
    for c in outs:
        assert c.tokens.shape == (4,)
        assert c.prefill_s >= 0 and c.decode_s >= 0


def test_generate_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(1)
    reqs = [Request(uid=0, tokens=rng.integers(1, cfg.vocab_size, 5),
                    max_new_tokens=6)]
    a = eng.generate(reqs)[0].tokens
    b = eng.generate(reqs)[0].tokens
    np.testing.assert_array_equal(a, b)


def _groups():
    return [
        DeviceProfile("fast", "tpu-group", LinearTimeModel(a=1e-6), NO_COPY),
        DeviceProfile("slow", "tpu-group", LinearTimeModel(a=3e-6), NO_COPY),
    ]


def test_dispatcher_balances_by_speed():
    disp = PoasDispatcher(_groups())
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, tokens=rng.integers(1, 100, 16),
                    max_new_tokens=16) for i in range(40)]
    buckets = disp.split(reqs)
    tok = [sum(len(r.tokens) + r.max_new_tokens for r in b) for b in buckets]
    assert sum(len(b) for b in buckets) == 40
    # 3x speed ratio -> fast gets ~3x the tokens
    assert tok[0] / max(tok[1], 1) == pytest.approx(3.0, rel=0.3)


def test_dispatcher_preserves_all_requests():
    disp = PoasDispatcher(_groups())
    reqs = [Request(uid=i, tokens=np.arange(1 + i % 7), max_new_tokens=2)
            for i in range(17)]
    buckets = disp.split(reqs)
    uids = sorted(r.uid for b in buckets for r in b)
    assert uids == list(range(17))


def test_dispatcher_empty():
    disp = PoasDispatcher(_groups())
    assert disp.split([]) == [[], []]
