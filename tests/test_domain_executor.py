"""Domain registry, PlanCache, and overlapped-executor runtime tests."""
import time

import numpy as np
import pytest

from repro.core import (DeviceTask, GemmWorkload, HGemms, OverlappedExecutor,
                        PlanCache, POAS, Timeline, get_domain, list_domains,
                        paper_mach1, paper_mach2, simulate_timeline)
from repro.core.adapt import pack_largest_first, round_shares_to_grain
from repro.core.domain import device_signature
from repro.core.executor import TicketBus


# ---------------------------------------------------------------- registry --

def test_builtin_domains_registered():
    names = list_domains()
    assert {"gemm", "serving-dispatch", "train-step"} <= set(names)


def test_get_domain_builds_gemm():
    dom = get_domain("gemm", paper_mach1())
    plan = POAS(dom).plan(GemmWorkload(2048, 1024, 512))
    assert plan.adapted.total_rows() == 2048


def test_get_domain_unknown_raises():
    with pytest.raises(KeyError, match="unknown POAS domain"):
        get_domain("no-such-domain")


# ---------------------------------------------------- schedule finish times --

def test_schedule_finish_times_are_per_device():
    hg = HGemms(paper_mach2())
    plan = hg.plan(30000, 30000, 30000)
    res, tl = plan.schedule.result, plan.schedule.timeline
    # per-device finish times come from the timeline, not the makespan
    for d, f in zip(hg.devices, res.finish_times):
        assert f == pytest.approx(tl.device_finish(d.name))
    busy = [f for f in res.finish_times if f > 0]
    assert len(set(busy)) > 1          # devices finish at different times
    assert max(res.finish_times) == pytest.approx(tl.makespan)


# ---------------------------------------------------------------- executor --

def _bus_events(tl: Timeline):
    return sorted((e for e in tl.events if e.kind != "compute"),
                  key=lambda e: e.start)


def test_executor_matches_simulated_event_order():
    """Acceptance: measured busy intervals preserve the planned bus
    serialization and priority order of ``simulate_timeline``."""
    hg = HGemms(paper_mach2())
    rng = np.random.default_rng(0)
    a = rng.standard_normal((1024, 256)).astype(np.float32)
    b = rng.standard_normal((256, 512)).astype(np.float32)
    c, rep = hg.execute(a, b)
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)
    assert rep.measured is not None

    planned = rep.plan.schedule.timeline
    measured = rep.measured
    # 1. every planned stage ran exactly once
    assert sorted((e.device, e.kind) for e in measured.events) == \
        sorted((e.device, e.kind) for e in planned.events)
    # 2. bus transfers never overlap and follow the planned order
    plan_order = [(e.device, e.kind) for e in _bus_events(planned)]
    meas = _bus_events(measured)
    assert [(e.device, e.kind) for e in meas] == plan_order
    for x, y in zip(meas, meas[1:]):
        assert y.start >= x.end - 1e-9
    # 3. per-device stage order: copy_in < compute < copy_out
    for name in {e.device for e in measured.events}:
        evs = {e.kind: e for e in measured.device_events(name)}
        if "copy_in" in evs:
            assert evs["compute"].start >= evs["copy_in"].end - 1e-9
        if "copy_out" in evs:
            assert evs["copy_out"].start >= evs["compute"].end - 1e-9


def test_executor_overlaps_compute_with_copies():
    """A lower-priority device's bus copy may only start after the
    higher-priority copy ends, but high-priority compute runs meanwhile."""
    devs = paper_mach2()
    hg = HGemms(devs)
    rng = np.random.default_rng(1)
    a = rng.standard_normal((2048, 512)).astype(np.float32)
    b = rng.standard_normal((512, 256)).astype(np.float32)
    _, rep = hg.execute(a, b)
    meas = rep.measured
    copies = [e for e in meas.events if e.kind == "copy_in"]
    if len(copies) >= 2:
        first = min(copies, key=lambda e: e.start)
        comp = {e.kind: e for e in meas.device_events(first.device)}["compute"]
        later = max(copies, key=lambda e: e.start)
        # the first device's compute window may overlap the later copy
        assert comp.start >= first.end - 1e-9
        assert later.start >= first.end - 1e-9


def test_executor_propagates_stage_errors():
    devs = paper_mach1()
    ops = [1e9] * len(devs)
    planned = simulate_timeline(devs, ops, 1000, 1000)

    def boom():
        raise RuntimeError("stage failed")

    tasks = [DeviceTask(device=devs[0].name, copy_in=None, compute=boom,
                        copy_out=None)]
    with pytest.raises(RuntimeError, match="stage failed"):
        OverlappedExecutor(devs, planned).run(tasks)


def test_executor_subset_task_list_does_not_hang():
    """Tasks covering only some planned devices must release the unclaimed
    bus tickets instead of wedging the grant sequence."""
    devs = paper_mach2()
    ops = [1e12] * len(devs)
    planned = simulate_timeline(devs, ops, 4000, 4000)
    ran = []
    # only the *last*-priority copy device runs; its tickets sit behind the
    # missing faster device's in the planned sequence
    gpu = next(d for d in devs if d.name == "3090-cuda")
    tasks = [DeviceTask(device=gpu.name,
                        copy_in=lambda: ran.append("in"),
                        compute=lambda: ran.append("compute"),
                        copy_out=lambda: ran.append("out"))]
    measured = OverlappedExecutor(devs, planned).run(tasks)
    assert ran == ["in", "compute", "out"]
    assert {e.device for e in measured.events} == {gpu.name}


def test_ticket_bus_orders_grants():
    seq = [("a", "copy_in"), ("b", "copy_in")]
    bus = TicketBus(seq)
    with pytest.raises(ValueError):
        bus.acquire(("c", "copy_in"))
    bus.acquire(("a", "copy_in"))   # first ticket is immediately grantable
    bus.release(("a", "copy_in"))
    bus.acquire(("b", "copy_in"))
    bus.release(("b", "copy_in"))


# --------------------------------------------------------------- plan cache --

def test_plan_cache_hit_is_fast_and_identical():
    hg = HGemms(paper_mach2())
    m = n = k = 30000
    t0 = time.perf_counter()
    p1 = hg.plan(m, n, k)
    t_solve = time.perf_counter() - t0
    t0 = time.perf_counter()
    p2 = hg.plan(m, n, k)
    t_hit = time.perf_counter() - t0
    # memoized: the solved phases are shared, the workload is the caller's
    assert p2.adapted is p1.adapted and p2.schedule is p1.schedule
    assert p2.workload == p1.workload
    assert hg.plan_cache.hits == 1
    # acceptance: cached call >= 10x faster than the solve
    assert t_hit < t_solve / 10.0, (t_solve, t_hit)


def test_plan_cache_distinguishes_geometry():
    hg = HGemms(paper_mach1())
    hg.plan(2048, 1024, 512)
    hg.plan(4096, 1024, 512)
    assert hg.plan_cache.hits == 0
    assert hg.plan_cache.misses == 2
    assert len(hg.plan_cache) == 2


def test_plan_cache_invalidated_by_dynamic_refit():
    hg = HGemms(paper_mach1(), dynamic=True)
    m = n = k = 20000
    p1 = hg.plan(m, n, k)
    assert hg.plan(m, n, k).adapted is p1.adapted
    # a refit observation must flush the cache AND change the device key
    sig0 = device_signature(hg.poas.domain.predict())
    hg.dyn.observe(1, 1e12, hg.devices[1].compute(1e12) * 4.0)
    assert len(hg.plan_cache) == 0
    assert hg.plan_cache.invalidations >= 1
    assert device_signature(hg.poas.domain.predict()) != sig0
    p2 = hg.plan(m, n, k)
    assert p2.adapted is not p1.adapted  # re-solved under re-fitted models


def test_plan_cache_lru_eviction():
    cache = PlanCache(maxsize=2)
    cache.put("a", 1)
    cache.put("b", 2)
    cache.put("c", 3)
    assert cache.get("a") is None      # evicted
    assert cache.get("c") == 3


# ------------------------------------------------------- adapt primitives --

def test_pack_largest_first_tracks_budgets():
    weights = [5, 3, 8, 1, 4, 2]
    budgets = [15.0, 8.0]
    buckets = pack_largest_first(weights, budgets)
    assert sorted(i for b in buckets for i in b) == list(range(6))
    tot = [sum(weights[i] for i in b) for b in buckets]
    for t, budget in zip(tot, budgets):
        assert abs(t - budget) <= max(weights)


def test_round_shares_to_grain_conserves_total():
    sizes = round_shares_to_grain([10.3, 21.7, 0.0], [8, 8, 8], 32)
    assert sum(sizes) == 32
    assert all(s % 8 == 0 for s in sizes)


def test_round_shares_to_grain_handles_overassignment():
    # floors (16 + 8) exceed the total; trimming must restore conservation
    sizes = round_shares_to_grain([16.0, 8.0], [8, 8], 16)
    assert sum(sizes) == 16
    assert all(s % 8 == 0 for s in sizes)
