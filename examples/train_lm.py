"""End-to-end driver: train a reduced-config LM for a few hundred steps with
checkpointing, an injected mid-run failure, and resume — the fault-tolerance
path a real fleet exercises.

    PYTHONPATH=src python examples/train_lm.py [--arch stablelm-12b]
"""
import argparse
import shutil
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        print("=== phase 1: train to step", args.steps // 2, "===")
        train_main(["--arch", args.arch, "--tiny",
                    "--steps", str(args.steps // 2),
                    "--batch", "8", "--seq", "64",
                    "--ckpt-dir", ckpt, "--ckpt-every", "25",
                    "--log-every", "25"])
        print("\n=== phase 2: 'crash', then resume from checkpoint ===")
        train_main(["--arch", args.arch, "--tiny",
                    "--steps", str(args.steps),
                    "--batch", "8", "--seq", "64",
                    "--ckpt-dir", ckpt, "--ckpt-every", "25",
                    "--resume", "--log-every", "25"])
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
