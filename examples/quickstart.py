"""Quickstart: the POAS pipeline end-to-end on the paper's GEMM case study.

Runs Predict (profiling + regression) -> Optimize (min-makespan) ->
Adapt (ops_to_mnk) -> Schedule (priority bus timeline) on the simulated
mach2 testbed, then executes a real (numerically checked) co-executed
matmul on this host.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.core import (HGemms, Profiler, list_domains, paper_mach2,
                        simulated_runner)


def main():
    # ---- Predict: profile each device (simulated testbed, real noise) ----
    truth = paper_mach2()
    devices = []
    for i, dev in enumerate(truth):
        sizes = range(1000, 2001, 100) if dev.kind == "cpu" else \
            range(3000, 6001, 300)
        prof = Profiler(simulated_runner(dev, noise=0.02, seed=i), repeats=5)
        prof.run(sizes)
        fitted = prof.fit()
        print(f"[predict] {dev.name:15s} fitted a={fitted.a:.3e} s/op "
              f"b={fitted.b*1e3:.2f} ms")
        devices.append(dataclasses.replace(dev, compute=fitted))

    # ---- Optimize + Adapt + Schedule via the DS-POAS for GEMM ----
    print(f"\nregistered POAS domains: {list_domains()}")
    hg = HGemms(devices)
    m = n = k = 30_000
    plan = hg.plan(m, n, k)
    hg.plan(m, n, k)   # same geometry: served from the PlanCache
    print(f"plan cache after repeat: {hg.plan_cache.stats()}")
    print(f"\n[optimize] makespan {plan.schedule.timeline.makespan:.3f}s "
          f"for {m}x{n}x{k} ({m*n*k/1e12:.1f} TOps)")
    for asg in plan.adapted.assignments:
        share = asg.ops / (float(m) * n * k) * 100
        print(f"[adapt]    {asg.device:15s} rows {asg.row0:>6}..."
              f"{asg.row0+asg.m:>6}  ({share:5.2f}%, "
              f"{len(asg.sub_products)} square sub-products)")
    for ev in sorted(plan.schedule.timeline.events, key=lambda e: e.start):
        print(f"[schedule] {ev.start*1e3:8.1f}ms -> {ev.end*1e3:8.1f}ms  "
              f"{ev.device:15s} {ev.kind}")

    # ---- Execute a real (small) co-executed GEMM on this host ----
    # Partitions run through the overlapped runtime: thread per device,
    # copies serialized on the shared bus in priority order.
    rng = np.random.default_rng(0)
    a = rng.standard_normal((1024, 512)).astype(np.float32)
    b = rng.standard_normal((512, 768)).astype(np.float32)
    c, rep = hg.execute(a, b)
    err = np.max(np.abs(c - a @ b))
    print(f"\n[execute] real co-executed GEMM max|err|={err:.2e}  "
          f"speedup vs best single device: "
          f"{min(rep.speedups.values()):.2f}x-{max(rep.speedups.values()):.0f}x")
    for ev in rep.measured.events:
        print(f"[measured] {ev.start*1e3:8.2f}ms -> {ev.end*1e3:8.2f}ms  "
              f"{ev.device:15s} {ev.kind}")


if __name__ == "__main__":
    main()
