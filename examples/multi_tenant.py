"""Multi-tenant co-execution: weighted-fair, SLO-aware, preemptive.

Two tenants share ONE runtime (one link namespace, one carried-clock
timeline) on the paper's mach1 testbed: a batch tenant streaming
transformer-block DAGs, and a latency-tier tenant firing small diamond
DAGs open-loop into the middle of the backlog.  The same arrival
schedule runs twice — plain FIFO admission, then SFQ weighted-fair
admission with priority preemption — and the latency tier's percentiles
collapse while total makespan stays put (DESIGN.md §13).  An
infeasible-deadline job is rejected at admission in both runs: predicted
completion on the carried clocks is the SLO gate.

    PYTHONPATH=src python examples/multi_tenant.py
"""
from repro.core import (AdmissionRejected, CoExecutionRuntime, QoS,
                        TIER_LATENCY, TaskGraphDomain, diamond,
                        paper_mach1, transformer_block,
                        truth_from_profiles, verify_stream_invariants)

N_BATCH = 8
N_LATENCY = 6


def _block():
    return transformer_block(d_model=2048, seq=4096, groups=4)


def run(admission: str, preempt: bool, M: float):
    rt = CoExecutionRuntime(None, executor="virtual",
                            truth=truth_from_profiles(paper_mach1()),
                            feedback=True, max_inflight=2,
                            admission=admission, preempt=preempt)
    try:
        batch = rt.register("batch", TaskGraphDomain(
            paper_mach1(), bus="serialized", dynamic=True), QoS(weight=1.0))
        lat = rt.register("latency", TaskGraphDomain(
            paper_mach1(), bus="serialized", dynamic=True),
            QoS(weight=4.0, tier=TIER_LATENCY))
        rt.pause_admission()
        for _ in range(N_BATCH):
            batch.submit(_block(), arrival=0.0)
        for i in range(N_LATENCY):
            lat.submit(diamond(ops=2e9, width=3), arrival=(0.5 + i) * M)
        doomed = lat.submit(diamond(ops=2e9, width=3), arrival=0.5 * M,
                            deadline_s=1e-6)
        rt.resume_admission()
        rt.drain()
        assert doomed.rejected and isinstance(doomed.error,
                                              AdmissionRejected)
        assert verify_stream_invariants(list(rt.jobs)) == []
        stats = rt.stats()
        splices = sum(1 for j in rt.jobs for r in j.replans
                      if r.reason == "preempt")
        return stats, splices
    finally:
        rt.shutdown()


def main():
    # one block's solo makespan anchors the open-loop arrival schedule
    with CoExecutionRuntime(
            TaskGraphDomain(paper_mach1(), bus="serialized", dynamic=True),
            executor="virtual", truth=truth_from_profiles(paper_mach1()),
            max_inflight=1) as probe:
        M = probe.run_stream([_block()])[0].measured.makespan

    print(f"{'config':<14} {'lat p50':>9} {'lat p99':>9} "
          f"{'batch p99':>10} {'total':>9} {'splices':>8}")
    for label, admission, preempt in (("fifo", "fifo", False),
                                      ("fair+preempt", "fair", True)):
        stats, splices = run(admission, preempt, M)
        t = stats["tenants"]
        print(f"{label:<14} {t['latency']['p50_latency_s']*1e3:8.2f}m "
              f"{t['latency']['p99_latency_s']*1e3:8.2f}m "
              f"{t['batch']['p99_latency_s']*1e3:9.2f}m "
              f"{stats['total_makespan_s']*1e3:8.2f}m {splices:>8}")
        assert stats["rejected"] == 1    # the SLO gate fired in both runs
    print("\nlatency tier jumps the backlog (strict tier priority), SFQ "
          "keeps batch tenants\nweight-proportional, and preemption "
          "revokes in-flight batch tickets — same\ntotal makespan, "
          "collapsed tail latency.")


if __name__ == "__main__":
    main()
