"""Task-graph co-execution: a transformer block scheduled as a DAG.

The paper's domains split one divisible workload by share; a transformer
block (grouped QKV/attention heads → projection → residual → grouped MLP)
has *structure* — 19+ tasks with precedence edges.  The ``task-graph``
domain list-schedules it across CPU/GPU/XPU on the shared timeline engine:
cross-device edges become host-staged link copies, same-device edges are
free, and the HEFT-style solver (upward-rank priority, earliest-finish
placement, degenerate-seed descent) beats the best single device
(DESIGN.md §10).  A second section streams DAG jobs through the
``CoExecutionRuntime`` with a mid-stream throttle: per-task observations
re-fit the models and later plans shed the slow device.  A third section
shows mid-graph re-planning (DESIGN.md §11): the throttle hits while a
DAG job is already *in flight* — the straggler monitor freezes the
completed/running tasks, re-solves the not-yet-started frontier under the
re-fitted models, and splices the new assignment into the live run,
beating the locked-in plan.

    PYTHONPATH=src python examples/graph_coexec.py
"""
from repro.core import (CoExecutionRuntime, TaskGraphDomain,
                        graph_finish_times, paper_mach2, solve_list_schedule,
                        transformer_block, truth_from_profiles,
                        verify_graph_dependencies, verify_stream_invariants)

CASE_STUDY = dict(d_model=4096, seq=16384, ff_mult=4, groups=8)
N_JOBS = 8
THROTTLE_AT = 3
THROTTLE = 3.0


def main():
    devs = paper_mach2()
    g = transformer_block(**CASE_STUDY)
    cp_ops, cp_path = g.critical_path()
    print(f"transformer block: {len(g)} tasks, {g.total_ops()/1e12:.2f} "
          f"TOps, critical path {cp_ops/g.total_ops():.0%} of total "
          f"({' -> '.join(p.split('.')[-1] for p in cp_path)})")

    res = solve_list_schedule(devs, g.task_specs(), g.edge_indices(),
                              bus="serialized")
    print(f"\n{'device':>14} {'tasks':>6} {'ops share':>10}")
    for j, d in enumerate(devs):
        names = [g.nodes[i].name.split(".")[-1]
                 for i in range(len(g)) if res.assign[i] == j]
        print(f"{d.name:>14} {len(names):>6} {res.shares()[j]:>10.1%}  "
              f"{', '.join(names[:6])}{'...' if len(names) > 6 else ''}")

    singles = {d.name: max(graph_finish_times(
        devs, g.task_specs(), g.edge_indices(), [j] * len(g),
        topology="serialized", order=res.order))
        for j, d in enumerate(devs)}
    best = min(singles, key=singles.get)
    tl = res.makespan
    print(f"\nco-execution makespan {tl*1e3:.1f}ms vs best single device "
          f"({best}) {singles[best]*1e3:.1f}ms -> "
          f"{singles[best]/tl:.2f}x speedup")

    # stream DAG jobs through the runtime; throttle the fastest device
    fast = max(devs, key=lambda d: d.effective_speed).name
    truth = truth_from_profiles(
        paper_mach2(), lambda uid, name: THROTTLE
        if uid >= THROTTLE_AT and name == fast else 1.0)
    small = transformer_block(d_model=1024, seq=2048, groups=4)
    dom = TaskGraphDomain(paper_mach2(), bus="serialized", dynamic=True)
    with CoExecutionRuntime(dom, executor="virtual", truth=truth,
                            feedback=True, max_inflight=1) as rt:
        jobs = rt.run_stream([small] * N_JOBS)
        print(f"\n{'job':>4} {'per-device ops shares':>28} {'span':>9}")
        for j in jobs:
            s = j.plan.optimize.shares()
            tag = f"  <- {fast} throttles {THROTTLE:.0f}x" \
                if j.uid == THROTTLE_AT else ""
            print(f"{j.uid:>4} " + " ".join(f"{x:>8.1%}" for x in s)
                  + f" {j.span*1e3:8.2f}ms{tag}")
        print(f"\nper-task observations: {rt.pump.observations}, "
              f"re-fits: {dom.dyn.epoch}, plan-cache invalidations: "
              f"{rt.plan_cache.invalidations}")
        assert verify_stream_invariants(jobs) == []
        for j in jobs:
            assert verify_graph_dependencies(j.plan.schedule.spec,
                                             j.measured) == []
    print("dependency + per-link invariants clean on every measured "
          "timeline")

    # mid-graph re-planning: the throttle is active from job 0, so the very
    # first plan (solved with stale nominal models) straggles mid-DAG
    always = truth_from_profiles(
        paper_mach2(), lambda uid, name: THROTTLE if name == fast else 1.0)
    spans = {}
    for replan in (False, True):
        dom = TaskGraphDomain(paper_mach2(), bus="serialized", dynamic=True)
        with CoExecutionRuntime(dom, executor="virtual", truth=always,
                                feedback=True, max_inflight=1,
                                replan=replan) as rt:
            jobs = rt.run_stream([small])
            j = jobs[0]
            spans[replan] = j.span
            assert verify_stream_invariants(jobs) == []
            assert verify_graph_dependencies(j.final_spec, j.measured) == []
            if replan and j.replans:
                r = j.replans[0]
                print(f"\nmid-graph re-plan: straggler "
                      f"{r.straggler.split('.')[-1]} detected at "
                      f"{r.at*1e3:.2f}ms -> froze {len(r.frozen)} "
                      f"started tasks, re-issued {len(r.spliced)} "
                      f"not-yet-started successors")
    print(f"locked-in {spans[False]*1e3:.2f}ms vs re-planned "
          f"{spans[True]*1e3:.2f}ms -> {spans[False]/spans[True]:.2f}x, "
          "invariants clean across the splice")


if __name__ == "__main__":
    main()
