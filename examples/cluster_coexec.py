"""Device-to-cluster scheduling: hierarchy, energy knob, device loss.

The §16 walkthrough (DESIGN.md) on a synthetic 2-host cluster — host
``h0`` holds a 40 and a 30 TFLOP/s accelerator on one staging link, host
``h1`` a second 40 TFLOP/s part, and the hosts talk over a capped NIC.
Three acts:

1. **Cluster-aware placement** — a layered all-to-all DAG solved under
   the real hierarchy vs under ``topology.flatten()`` (the NIC-oblivious
   single-host view), with the flat plan re-priced under cluster truth:
   the flat planner *believes* it is faster, and the gap is exactly the
   NIC traffic it cannot see.
2. **The energy knob** — the same solver with ``Objective(w)`` sweeping
   the makespan/joules exchange rate on powered device profiles: w=0 is
   bit-identical to no objective at all; larger w shifts work onto the
   efficient host at a priced makespan cost.
3. **Device loss mid-stream** — a job planned on all three devices meets
   a ground truth where ``h1.a`` runs 50x slow (a dying part);
   ``device_leave`` freezes what ran, re-solves the frontier with the
   device banned (resident outputs drained to the host), splices, and
   beats riding the stale plan — while the next admission plans on the
   surviving devices automatically.

    PYTHONPATH=src python examples/cluster_coexec.py
"""
from repro.core import (BusTopology, CoExecutionRuntime, Objective,
                        TaskGraphDomain, graph_finish_times,
                        solve_list_schedule, truth_from_profiles,
                        verify_graph_dependencies)
from repro.core.device_model import CopyModel, DeviceProfile, LinearTimeModel
from repro.core.graph import TaskGraph, TaskNode

DEAD_FACTOR = 50.0


def device(name, tflops, *, idle_w=0.0, jpo=0.0, copy_bw=15.75e9):
    return DeviceProfile(name, "gpu",
                         LinearTimeModel(2.0 / (tflops * 1e12), 1e-6),
                         CopyModel(copy_bw, dtype_size=2),
                         idle_watts=idle_w, joules_per_op=jpo)


def cluster(devs, nic_bw):
    return BusTopology.cluster({"h0": devs[:2], "h1": devs[2:]},
                               nic_bandwidth_bytes_per_s=nic_bw,
                               nic_latency_s=1e-5)


def layered(width, layers, ops, nbytes):
    nodes, edges = [], []
    for l in range(layers):
        for w in range(width):
            nodes.append(TaskNode(f"l{l}.t{w}", ops, nbytes, nbytes))
            if l:
                edges.extend((f"l{l-1}.t{p}", f"l{l}.t{w}")
                             for p in range(width))
    return TaskGraph(tuple(nodes), tuple(edges))


def chains(n_chains, n_stages, ops=5e9, nbytes=1e5):
    nodes, edges = [], []
    for c in range(n_chains):
        for s in range(n_stages):
            nodes.append(TaskNode(f"c{c}.s{s}", ops, nbytes, nbytes))
            if s:
                edges.append((f"c{c}.s{s-1}", f"c{c}.s{s}"))
    return TaskGraph(tuple(nodes), tuple(edges))


def main():
    # --- act 1: the NIC the flat planner cannot see ------------------------
    devs = [device("h0.a", 40.0, copy_bw=100e9),
            device("h0.b", 30.0, copy_bw=100e9),
            device("h1.a", 40.0, copy_bw=100e9)]
    topo = cluster(devs, nic_bw=1e9)
    g = layered(width=4, layers=6, ops=1e10, nbytes=4e6)
    tasks, edges = g.task_specs(), g.edge_indices()
    aware = solve_list_schedule(devs, tasks, edges, bus=topo)
    flat = solve_list_schedule(devs, tasks, edges, bus=topo.flatten())
    flat_truth = max(graph_finish_times(devs, tasks, edges, flat.assign,
                                        topology=topo, order=flat.order))
    print(f"layered DAG, {len(tasks)} tasks: cluster-aware "
          f"{aware.makespan*1e3:.2f}ms; flat plan believed "
          f"{flat.makespan*1e3:.2f}ms, really costs "
          f"{flat_truth*1e3:.2f}ms -> {flat_truth/aware.makespan:.2f}x "
          f"win for seeing the NIC")

    # --- act 2: the makespan/energy exchange rate --------------------------
    powered = [device("h0.a", 40.0, idle_w=2.0, jpo=4e-10),
               device("h0.b", 30.0, idle_w=1.5, jpo=3e-10),
               device("h1.a", 40.0, idle_w=0.5, jpo=0.8e-10)]
    ptopo = cluster(powered, nic_bw=2e9)
    g2 = chains(2, 4)
    t2, e2 = g2.task_specs(), g2.edge_indices()
    print("\n  weight (s/J)   makespan     energy")
    for w in (0.0, 2e-5, 1e-4, 5e-4, 2e-3):
        r = solve_list_schedule(powered, t2, e2, bus=ptopo,
                                objective=Objective(energy_weight=w),
                                exhaustive_limit=20000, max_evals=20001)
        print(f"  {w:>12g}   {r.makespan*1e3:6.3f}ms   {r.energy_j:6.2f}J")

    # --- act 3: device loss as a change-point ------------------------------
    base = [device("h0.a", 40.0), device("h0.b", 30.0),
            device("h1.a", 40.0)]
    truth = truth_from_profiles(
        base, lambda uid, name: DEAD_FACTOR if name == "h1.a" else 1.0)
    g3 = chains(6, 4)

    def run(rescue):
        devs = [device("h0.a", 40.0), device("h0.b", 30.0),
                device("h1.a", 40.0)]
        dom = TaskGraphDomain(devs, bus=cluster(devs, 2e9), dynamic=True)
        with CoExecutionRuntime(dom, executor="virtual", truth=truth,
                                feedback=False, max_inflight=1) as rt:
            job = rt.submit(g3)
            job.wait(60)
            if not rescue:
                return job.measured.makespan, None, None, None
            planned = job.plan.schedule.timeline.makespan
            recs = rt.device_leave("h1.a", at=0.25 * planned)
            viol = verify_graph_dependencies(recs[-1].spec, job.measured)
            nxt = rt.submit(g3)
            nxt.wait(60)
            return job.measured.makespan, recs[-1], nxt, viol

    locked, _, _, _ = run(rescue=False)
    rescued, rec, nxt, viol = run(rescue=True)
    print(f"\nh1.a dies ({DEAD_FACTOR:.0f}x slow under truth): locked-in "
          f"plan {locked*1e3:.2f}ms; rescue at t={rec.at*1e3:.2f}ms "
          f"(reason {rec.reason!r}, {len(rec.frozen)} frozen / "
          f"{len(rec.spliced)} re-solved) finishes {rescued*1e3:.2f}ms "
          f"-> {locked/rescued:.2f}x")
    survivors = sorted({e.device for e in nxt.measured.events})
    print(f"next admission plans on {survivors} (departed device gone); "
          f"dependency violations: {len(viol)}")


if __name__ == "__main__":
    main()
