"""Streaming co-execution: the plan→execute→observe→re-plan loop.

A sustained stream of GEMM jobs flows through the persistent
``CoExecutionRuntime`` on the paper's mach1 testbed.  Mid-stream the XPU
(2080 Ti tensor cores) thermally throttles 3x; the observation pump feeds
each job's measured compute times back into the DynamicScheduler, which
re-fits the device model (one change-point window reset), invalidates the
PlanCache, and the very next planned job sheds load off the throttled
device — no caller wiring, the loop does it (DESIGN.md §9).

    PYTHONPATH=src python examples/streaming_coexec.py
"""
from repro.core import (CoExecutionRuntime, GemmDomain, GemmWorkload,
                        paper_mach1, truth_from_profiles,
                        verify_stream_invariants)

N_JOBS = 20
THROTTLE_AT = 6
THROTTLE = 3.0
SHAPE = GemmWorkload(4096, 4096, 4096)


def main():
    truth = truth_from_profiles(
        paper_mach1(),
        lambda uid, name: THROTTLE
        if uid >= THROTTLE_AT and name == "2080ti-tensor" else 1.0)

    results = {}
    for label, feedback in (("static", False), ("feedback", True)):
        domain = GemmDomain(paper_mach1(), bus="serialized", dynamic=feedback)
        with CoExecutionRuntime(domain, executor="virtual", truth=truth,
                                feedback=feedback, carry_clocks=True,
                                max_inflight=2) as rt:
            jobs = rt.run_stream([SHAPE] * N_JOBS)
            results[label] = (rt.total_makespan(), jobs)
            if feedback:
                print(f"{'job':>4} {'cpu/gpu/xpu shares':>24} "
                      f"{'span':>8}")
                for j in jobs:
                    s = j.plan.optimize.shares()
                    tag = ("  <- xpu throttles 3x"
                           if j.uid == THROTTLE_AT else "")
                    print(f"{j.uid:>4} {s[0]:>7.1%} {s[1]:>7.1%} "
                          f"{s[2]:>7.1%} {j.span*1e3:7.2f}ms{tag}")
                print(f"\nre-fits: {domain.dyn.epoch}, window resets: "
                      f"{domain.dyn.window_resets}, plan-cache "
                      f"invalidations: {rt.plan_cache.invalidations}")
        assert verify_stream_invariants(jobs) == [], "invariants violated"

    t_static, _ = results["static"]
    t_fb, _ = results["feedback"]
    print(f"\ntotal stream makespan: static plan {t_static*1e3:.1f}ms, "
          f"feedback loop {t_fb*1e3:.1f}ms "
          f"({t_static/t_fb:.2f}x) — measured timelines pass the per-link "
          f"serialization invariants across all {N_JOBS} plan boundaries")


if __name__ == "__main__":
    main()
