"""Batched serving with POAS request dispatch across heterogeneous replicas.

Serves a reduced-config model: a batch of prompts is split across two
simulated replica groups (one 2x faster) by the POAS min-makespan dispatch,
then each group runs real prefill + greedy decode.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_tiny_config
from repro.core.device_model import DeviceProfile, LinearTimeModel, NO_COPY
from repro.models import Model
from repro.serving.engine import PoasDispatcher, Request, ServingEngine


def main():
    cfg = get_tiny_config("stablelm-12b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params)

    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i,
                tokens=rng.integers(1, cfg.vocab_size, rng.integers(4, 24)),
                max_new_tokens=8)
        for i in range(12)
    ]

    groups = [
        DeviceProfile("replica-fast", "tpu-group",
                      LinearTimeModel(a=1e-6, b=1e-3), NO_COPY),
        DeviceProfile("replica-slow", "tpu-group",
                      LinearTimeModel(a=2e-6, b=1e-3), NO_COPY),
    ]
    dispatcher = PoasDispatcher(groups)
    buckets = dispatcher.split(requests)
    tok = lambda rs: sum(len(r.tokens) + r.max_new_tokens for r in rs)
    print(f"dispatch: fast={len(buckets[0])} reqs ({tok(buckets[0])} tok)  "
          f"slow={len(buckets[1])} reqs ({tok(buckets[1])} tok)  "
          f"predicted makespan {dispatcher.predicted_makespan(buckets)*1e3:.2f}ms")
    assert tok(buckets[0]) > tok(buckets[1]), "fast replica should get more"

    t0 = time.perf_counter()
    done = []
    for g, bucket in enumerate(buckets):      # sequential here; parallel on a fleet
        done += engine.generate(bucket)
    dt = time.perf_counter() - t0
    total_new = sum(len(c.tokens) for c in done)
    print(f"generated {total_new} tokens for {len(done)} requests "
          f"in {dt:.2f}s ({total_new/dt:.0f} tok/s on 1 CPU)")
    for c in sorted(done, key=lambda c: c.uid)[:3]:
        print(f"  req {c.uid}: {c.tokens[:8]}")


if __name__ == "__main__":
    main()
