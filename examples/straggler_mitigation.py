"""Straggler mitigation via POAS dynamic scheduling (hetero data parallel).

Simulates a 2-pod training fleet where pod-1 thermally throttles to 40%
mid-run.  The DynamicScheduler re-fits pod throughput from measured step
times and re-splits the global batch; step time recovers to near the
post-throttle optimum instead of being dragged down by the straggler.

    PYTHONPATH=src python examples/straggler_mitigation.py
"""
import numpy as np

from repro.distributed.hetero import HeteroBatchScheduler, PodProfile

GLOBAL_BATCH = 256
SEQ = 4096
FLOPS_PER_TOKEN = 6 * 12e9       # ~12B-param model
STEPS = 30
THROTTLE_AT = 10
THROTTLE = 0.4


def true_step_time(pod_idx: int, rows: int, step: int) -> float:
    """Ground-truth simulator: pod1 throttles to 40% at THROTTLE_AT."""
    eff = 197e12 * 0.4            # 40% MFU
    if pod_idx == 1 and step >= THROTTLE_AT:
        eff *= THROTTLE
    return rows * SEQ * FLOPS_PER_TOKEN / (256 * eff) + 2e-3


def main():
    pods = [PodProfile("pod0", 256, 197e12, grain=16),
            PodProfile("pod1", 256, 197e12, grain=16)]
    sched = HeteroBatchScheduler(pods, flops_per_token=FLOPS_PER_TOKEN,
                                 seq_len=SEQ, dynamic=True)
    static = HeteroBatchScheduler(pods, flops_per_token=FLOPS_PER_TOKEN,
                                  seq_len=SEQ, dynamic=False)
    static_split = static.plan(GLOBAL_BATCH)

    print(f"{'step':>4} {'split':>9} {'step_time':>9} {'static':>9} "
          f"{'saving':>7}")
    dyn_times, static_times = [], []
    for step in range(STEPS):
        split = sched.plan(GLOBAL_BATCH)
        times = [true_step_time(i, r, step)
                 for i, r in enumerate(split.sizes)]
        t_dyn = max(times)
        t_static = max(true_step_time(i, r, step)
                       for i, r in enumerate(static_split.sizes))
        dyn_times.append(t_dyn)
        static_times.append(t_static)
        # measured step times flow back through the shared observation pump
        # (the same path the streaming runtime uses, DESIGN.md §9)
        sched.feed_step(split, {p.name: t for p, t in zip(pods, times)})
        tag = " <- pod1 throttles to 40%" if step == THROTTLE_AT else ""
        print(f"{step:>4} {split.sizes[0]:>4}/{split.sizes[1]:<4} "
              f"{t_dyn*1e3:8.1f}ms {t_static*1e3:8.1f}ms "
              f"{(1 - t_dyn/t_static)*100:6.1f}%{tag}")

    after = slice(THROTTLE_AT + 3, STEPS)
    save = 1 - np.mean(dyn_times[after]) / np.mean(static_times[after])
    print(f"\nPOAS dynamic rebalancing saves {save*100:.0f}% of step time "
          f"after the straggler appears (steady state)")
    # ideal split under throttle: pod0/pod1 capacity 1 : 0.4 -> ~183/73
    print(f"final split {sched.plan(GLOBAL_BATCH).sizes} "
          f"(ideal ≈ [182, 74])")


if __name__ == "__main__":
    main()
